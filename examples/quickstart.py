"""Quickstart: the three layers of the framework in one page.

  1. the ALock itself (threaded, real concurrency),
  2. the cluster simulator through the declarative Workload/Experiment
     API — the paper's headline comparison plus a phased hot-key storm.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import threading

from repro.core.lock_table import LockTable
from repro.experiments import Experiment, ExecOptions
from repro.workloads import Phase, Workload


def demo_lock_table():
    print("== 1. ALock lock table (threaded) ==")
    table = LockTable(n_nodes=2, locks_per_node=4)
    counter = {"v": 0}

    def worker(node):
        for i in range(500):
            with table.critical(node, i % 8):
                counter["v"] += 1

    ths = [threading.Thread(target=worker, args=(n,)) for n in (0, 1, 0, 1)]
    [t.start() for t in ths]
    [t.join() for t in ths]
    print(f"  counter={counter['v']} (expected 2000), "
          f"local_ops={table.stats.local_ops}, "
          f"remote_ops={table.stats.remote_ops}")


def demo_simulator():
    print("== 2. cluster simulator (5 nodes x 4 threads, 95% locality) ==")
    base = Workload("alock", n_nodes=5, threads_per_node=4, n_locks=100,
                    locality=0.95)
    storm = (Phase(frac=0.4), Phase(frac=0.2, zipf_s=3.0),
             Phase(frac=0.4))
    exp = (Experiment("quickstart", n_events=80_000,
                      options=ExecOptions(backend="auto"))
           .add_grid(base, alg=("alock", "spinlock", "mcs"))
           .add(base.replace(phases=storm), label="alock.hotkey_storm"))
    for label, _, br in exp.run():
        r = br.result(0)
        print(f"  {label:18s} {r.throughput_mops:7.2f} Mops/s "
              f"(passes={r.passes}, reacquires={r.reacquires})")


if __name__ == "__main__":
    demo_lock_table()
    demo_simulator()

"""The paper's evaluation app: a distributed lock table under a mixed-
locality workload, on (a) real threads and (b) the calibrated simulator.

Run: PYTHONPATH=src python examples/lock_table_cluster.py [--nodes 5]
"""
import argparse
import random
import threading
import time

from repro.core.batch import sweep
from repro.core.lock_table import LockTable
from repro.workloads import Workload


def threaded_cluster(nodes: int, tpn: int, locks_per_node: int,
                     locality: float, ops: int):
    table = LockTable(nodes, locks_per_node)
    t0 = time.perf_counter()

    def worker(node, seed):
        rng = random.Random(seed)
        for _ in range(ops):
            if rng.random() < locality:
                target_node = node
            else:
                target_node = rng.choice([n for n in range(nodes)
                                          if n != node])
            lk = target_node * locks_per_node + \
                rng.randrange(locks_per_node)
            with table.critical(node, lk):
                pass
    ths = [threading.Thread(target=worker, args=(n, 31 * n + i))
           for n in range(nodes) for i in range(tpn)]
    [t.start() for t in ths]
    [t.join() for t in ths]
    dt = time.perf_counter() - t0
    total = table.stats.ops
    print(f"  threaded: {total} ops in {dt:.2f}s "
          f"({total/dt/1e3:.1f} Kops/s wall) "
          f"local={table.stats.local_ops} remote={table.stats.remote_ops} "
          f"reacquires={table.stats.reacquires}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--tpn", type=int, default=3)
    ap.add_argument("--locality", type=float, default=0.9)
    ap.add_argument("--seeds", type=int, default=1,
                    help="independent simulator seeds per algorithm "
                         "(batched in one compile; >1 adds ±ci95)")
    args = ap.parse_args()
    if args.seeds < 1:
        ap.error(f"--seeds must be >= 1, got {args.seeds}")

    print(f"== threaded lock table ({args.nodes} nodes x {args.tpn} "
          f"threads, locality {args.locality:.0%}) ==")
    threaded_cluster(args.nodes, args.tpn, 8, args.locality, 400)

    print(f"== calibrated simulator, same topology, all algorithms "
          f"({args.seeds} seed{'s' if args.seeds > 1 else ''}) ==")
    algs = ("alock", "spinlock", "mcs")
    cfgs = [Workload(alg, args.nodes, args.tpn, 8 * args.nodes,
                     locality=args.locality) for alg in algs]
    for alg, br in zip(algs, sweep(cfgs, n_seeds=args.seeds,
                                   n_events=100_000)):
        print(f"  {alg:9s} {br.mean_mops:7.2f} ±{br.ci95_mops:.2f} Mops/s "
              f"(simulated)")


if __name__ == "__main__":
    main()

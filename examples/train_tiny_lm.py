"""End-to-end training driver: a ~6M-parameter yi-family LM trained for a
few hundred steps on the synthetic affine-mod corpus, with lease-guarded
async checkpointing and restart-from-latest.

Loss target: starts near ln(vocab)=7.6, converges toward the ln(3)=1.10
noise floor. Run:

  PYTHONPATH=src python examples/train_tiny_lm.py [--steps 300]
  PYTHONPATH=src python examples/train_tiny_lm.py --resume   # restart
"""
import argparse
import dataclasses
import time

from repro.configs import get_config
from repro.configs.base import LayerSpec, uniform_groups
from repro.train.loop import LoopConfig, Trainer
from repro.train.optimizer import OptConfig


def make_cfg():
    base = get_config("yi-9b")
    return dataclasses.replace(
        base.tiny(),
        name="yi-tiny-6m",
        d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
        d_ff=1024, vocab=2048,
        groups=uniform_groups(4, LayerSpec(mixer="attn", ffn="mlp")),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt", default="artifacts/ckpt_tiny_lm")
    args = ap.parse_args()

    cfg = make_cfg()
    loop = LoopConfig(steps=args.steps, ckpt_every=100, ckpt_dir=args.ckpt,
                      seq_len=128, batch_per_shard=2, n_shards=4,
                      log_every=20)
    opt = OptConfig(lr=3e-3, warmup_steps=30, total_steps=args.steps)
    tr = Trainer(cfg, opt, loop)
    from repro.models.params import param_count
    from repro.models.model import model_specs
    print(f"model: {cfg.name}, {param_count(model_specs(cfg)):,} params")
    t0 = time.time()
    state = tr.run(resume=args.resume)
    dt = time.time() - t0
    for h in tr.history:
        print(f"  step {h['step']:4d}  loss {h['loss']:.4f}  "
              f"gnorm {h['grad_norm']:.2f}")
    tok_s = (args.steps * loop.batch_per_shard * loop.n_shards *
             loop.seq_len) / max(dt, 1e-9)
    print(f"done: {dt:.1f}s ({tok_s:.0f} tok/s on CPU), "
          f"final step {int(state['step'])}; floor=ln(3)=1.10")


if __name__ == "__main__":
    main()

"""Serving example: train briefly on the affine-mod corpus, then serve
batched requests and verify the engine's generations follow the learned
process (tok[t+1] in {3*tok[t]+7+e mod m}).

Run: PYTHONPATH=src python examples/serve_decode.py [--train-steps 150]
"""
import argparse
import time

import jax.numpy as jnp
import numpy as np

try:
    from examples.train_tiny_lm import make_cfg
except ModuleNotFoundError:   # run as a plain script
    from train_tiny_lm import make_cfg
from repro.serve.engine import Engine, ServeConfig
from repro.train.data import SyntheticLM
from repro.train.loop import LoopConfig, Trainer
from repro.train.optimizer import OptConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = make_cfg()
    loop = LoopConfig(steps=args.train_steps, ckpt_every=10_000,
                      ckpt_dir="artifacts/ckpt_serve_demo", seq_len=128,
                      batch_per_shard=2, n_shards=4, log_every=50)
    tr = Trainer(cfg, OptConfig(lr=3e-3, warmup_steps=30,
                                total_steps=args.train_steps), loop)
    state = tr.run(resume=False)
    print("trained:", tr.history[-1])

    ds = SyntheticLM(cfg.vocab, 64, args.batch)
    prompts = ds.batch(0, 12345)["tokens"]
    eng = Engine(cfg, state["params"],
                 ServeConfig(max_new_tokens=args.new_tokens))
    t0 = time.time()
    out = eng.generate({"tokens": jnp.asarray(prompts)})
    dt = time.time() - t0
    m = ds.modulus
    full = np.concatenate([prompts[:, -1:], out], axis=1)
    ok = ((full[:, 1:] - (3 * full[:, :-1] + 7)) % m <= 2)
    print(f"generated {out.shape} in {dt:.1f}s "
          f"({out.size/dt:.0f} tok/s incl. prefill+compile)")
    print(f"process-consistency of generated tokens: {ok.mean():.1%} "
          f"(random would be {3/m:.1%})")
    print("sample:", full[0][:16])


if __name__ == "__main__":
    main()

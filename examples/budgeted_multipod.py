"""Budgeted cross-pod training demo (the ALock budget idea on the fabric).

Forces 8 host devices, builds a (pod=2, data=2, model=2) mesh, and runs the
cohort-collective pair: k-1 pod-local accumulation microbatches followed by
one cross-pod sync — printing the loss and the measured cross-pod collective
traffic of each program.

Run: PYTHONPATH=src python examples/budgeted_multipod.py [--budget 4]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.hlo_analysis import parse_collectives
from repro.models import model as M
from repro.models.params import init_tree
from repro.parallel.collectives import make_budgeted_steps
from repro.train.data import SyntheticLM
from repro.train.optimizer import OptConfig, init_opt_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=4,
                    help="microbatches per cross-pod sync (remote budget)")
    ap.add_argument("--outer", type=int, default=6)
    args = ap.parse_args()

    cfg = get_config("yi-9b").tiny()
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    from jax.sharding import NamedSharding, PartitionSpec as P
    rep = NamedSharding(mesh, P())
    pod_major = NamedSharding(mesh, P("pod"))
    params = jax.device_put(init_tree(M.model_specs(cfg),
                                      jax.random.key(0)), rep)
    opt_cfg = OptConfig(lr=5e-3, warmup_steps=5,
                        total_steps=args.outer * 2)
    opt = jax.device_put(init_opt_state(params), rep)
    init_acc, local_step, sync_step, _ = make_budgeted_steps(
        cfg, opt_cfg, mesh, n_pod=2)
    ds = SyntheticLM(cfg.vocab, 32, 4)
    jl = jax.jit(local_step)
    js = jax.jit(sync_step)

    with mesh:
        acc = jax.device_put(init_acc(params), pod_major)
        step = 0
        for outer in range(args.outer):
            for micro in range(args.budget):
                b = ds.batch(0, outer * args.budget + micro)
                batch_pod = jax.device_put(
                    {k: jnp.asarray(v).reshape(2, 2, -1)
                     for k, v in b.items()},
                    NamedSharding(mesh, P("pod", "data")))
                acc, loss = jl(params, acc, batch_pod)
            params, opt, acc, m = js(params, opt, acc,
                                     jnp.asarray(step, jnp.int32),
                                     args.budget)
            step += 1
            print(f"outer {outer}: loss={float(loss):.4f} "
                  f"gnorm={float(m['grad_norm']):.2f}")

        # collective traffic accounting per program (pod-sharded acc)
        b = ds.batch(0, 0)
        batch_pod = jax.device_put(
            {k: jnp.asarray(v).reshape(2, 2, -1) for k, v in b.items()},
            NamedSharding(mesh, P("pod", "data")))
        acc_sharded = jax.device_put(init_acc(params), pod_major)
        tl = jax.jit(local_step).lower(params, acc_sharded, batch_pod)\
            .compile().as_text()
        ts = jax.jit(sync_step).lower(params, opt, acc_sharded,
                                      jnp.asarray(0, jnp.int32),
                                      args.budget).compile().as_text()
    cl = parse_collectives(tl, 8)
    cs = parse_collectives(ts, 8)
    k = args.budget
    print(f"local_step collective bytes:  {cl.raw_bytes:,.0f}")
    print(f"sync_step  collective bytes:  {cs.raw_bytes:,.0f}")
    amort = (cl.raw_bytes * k + cs.raw_bytes) / k
    sync_every = cl.raw_bytes + cs.raw_bytes
    print(f"amortized/microbatch at budget={k}: {amort:,.0f} vs "
          f"sync-every-microbatch {sync_every:,.0f} "
          f"({sync_every/max(amort,1):.2f}x reduction)")


if __name__ == "__main__":
    main()

"""Model assembly: block-pattern decoder LM / encoder-decoder, with
scan-over-stacked-layers, caches, loss, prefill and decode entry points.

Parameter tree layout:
  {"embed", "pos_table"?, "unembed"?, "final_norm",
   "dec": (per-group tuple of per-pattern-element param trees, stacked R),
   "enc"?: {"groups": ..., "final_norm", "pos_table"}}
Cache tree layout mirrors "dec": (groups)(elements){...arrays stacked R...}.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import layers as L
from repro.models.params import ParamSpec, stack_specs
from repro.parallel.sharding import constrain

F32 = jnp.float32
AUX_COEF = 0.01


# ---------------------------------------------------------------------------
# Block (one pattern element) = mixer + [cross-attn] + ffn with pre-norms


def block_specs(cfg: ModelConfig, spec: LayerSpec):
    p: dict[str, Any] = {}
    if spec.mixer == "attn":
        p["mixer_norm"] = L.norm_specs(cfg, cfg.d_model)
        p["mixer"] = L.attn_specs(cfg, spec)
    elif spec.mixer == "mla":
        p["mixer_norm"] = L.norm_specs(cfg, cfg.d_model)
        p["mixer"] = L.mla_specs(cfg, spec)
    elif spec.mixer == "mamba2":
        p["mixer_norm"] = L.norm_specs(cfg, cfg.d_model)
        p["mixer"] = L.mamba2_specs(cfg, spec)
    if spec.cross_attn:
        p["xattn_norm"] = L.norm_specs(cfg, cfg.d_model)
        p["xattn"] = L.attn_specs(cfg, spec)
    if spec.ffn == "mlp":
        p["ffn_norm"] = L.norm_specs(cfg, cfg.d_model)
        p["mlp"] = L.mlp_specs(cfg)
    elif spec.ffn == "moe":
        p["ffn_norm"] = L.norm_specs(cfg, cfg.d_model)
        p["moe"] = L.moe_specs(cfg, spec)
    return p


def block_cache_specs(cfg: ModelConfig, spec: LayerSpec, batch: int,
                      seq: int):
    c: dict[str, Any] = {}
    if spec.mixer == "attn":
        c["mixer"] = L.attn_cache_specs(cfg, spec, batch, seq)
    elif spec.mixer == "mla":
        c["mixer"] = L.mla_cache_specs(cfg, spec, batch, seq)
    elif spec.mixer == "mamba2":
        c["mixer"] = L.mamba2_cache_specs(cfg, spec, batch, seq)
    if spec.cross_attn:
        xs = LayerSpec(mixer="attn", cross_attn=True)
        # cross caches are enc_seq-sized (small): keep full precision
        c["xattn"] = L.attn_cache_specs(cfg, xs, batch, cfg.enc_seq,
                                        allow_int8=False)
    return c


def block_apply(cfg: ModelConfig, spec: LayerSpec, params, x, ctx: L.Ctx,
                cache):
    aux = jnp.zeros((), F32)
    new_cache: dict[str, Any] = {}
    if spec.mixer != "none":
        h = L.norm_apply(cfg, params["mixer_norm"], x)
        if spec.mixer == "attn":
            h, nc = L.attn_apply(cfg, spec, params["mixer"], h, ctx,
                                 (cache or {}).get("mixer"))
        elif spec.mixer == "mla":
            h, nc = L.mla_apply(cfg, spec, params["mixer"], h, ctx,
                                (cache or {}).get("mixer"))
        else:
            h, nc = L.mamba2_apply(cfg, spec, params["mixer"], h, ctx,
                                   (cache or {}).get("mixer"))
        x = x + h
        if nc is not None:
            new_cache["mixer"] = nc
    if spec.cross_attn:
        xs_spec = LayerSpec(mixer="attn", cross_attn=True)
        h = L.norm_apply(cfg, params["xattn_norm"], x)
        h, nc = L.attn_apply(cfg, xs_spec, params["xattn"], h, ctx,
                             (cache or {}).get("xattn"))
        x = x + h
        if nc is not None:
            new_cache["xattn"] = nc
    if spec.ffn == "mlp":
        h = L.norm_apply(cfg, params["ffn_norm"], x)
        x = x + L.mlp_apply(cfg, params["mlp"], h)
    elif spec.ffn == "moe":
        h = L.norm_apply(cfg, params["ffn_norm"], x)
        h, a = L.moe_apply(cfg, spec, params["moe"], h, ctx)
        x = x + h
        aux = aux + a
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# Groups: lax.scan over stacked pattern repeats


def _run_groups(cfg: ModelConfig, groups, gparams, x, ctx: L.Ctx, gcaches):
    """gparams: tuple per group of tuple per element; gcaches aligned or None."""
    aux = jnp.zeros((), F32)
    new_caches = []
    for gi, (pattern, R) in enumerate(groups):
        eparams = gparams[gi]
        ecache = gcaches[gi] if gcaches is not None else tuple(
            {} for _ in pattern)

        # checkpoint at BLOCK granularity: a multi-element pattern (gemma3's
        # 5:1, jamba's 8-layer block) must not hold all elements' backward
        # intermediates live at once.
        remat_on = (cfg.remat != "none" and ctx.mode == "full"
                    and not ctx.build_cache)

        def apply_one(spec, ep_i, xx, ec_i):
            def f(ep_i, xx):
                return block_apply(cfg, spec, ep_i, xx, ctx,
                                   ec_i if ec_i else None)
            if remat_on:
                if cfg.remat == "dots":
                    f = jax.checkpoint(
                        f, policy=jax.checkpoint_policies.dots_saveable)
                else:
                    f = jax.checkpoint(f)
            return f(ep_i, xx)

        def body(carry, xs, pattern=pattern):
            xx, aa = carry
            ep, ec = xs
            ncs = []
            for i, spec in enumerate(pattern):
                xx, a, nc = apply_one(spec, ep[i], xx, ec[i])
                aa = aa + a
                ncs.append(nc)
            return (xx, aa), tuple(ncs)

        if R == 1:
            # unrolled group: no while loop (required for shard_map layers;
            # also removes loop overhead for singleton groups)
            ep0 = jax.tree_util.tree_map(lambda a: a[0], eparams)
            ec0 = jax.tree_util.tree_map(lambda a: a[0], ecache)
            (x, aux), nc0 = body((x, aux), (ep0, ec0))
            nc = jax.tree_util.tree_map(lambda a: a[None], nc0)
        else:
            (x, aux), nc = lax.scan(body, (x, aux), (eparams, ecache))
        new_caches.append(nc)
    return x, aux, tuple(new_caches)


# ---------------------------------------------------------------------------
# Full model specs


def _apply_dtype(tree, dtype):
    """Parameter specs default to bf16; honor cfg.dtype (tiny configs train
    in f32). Explicit f32 specs (norm scales, routers) stay f32."""
    from repro.models.params import is_spec
    return jax.tree_util.tree_map(
        lambda s: s._replace(dtype=dtype) if s.dtype == jnp.bfloat16 else s,
        tree, is_leaf=is_spec)


def model_specs(cfg: ModelConfig):
    D = cfg.d_model
    p: dict[str, Any] = {
        "embed": ParamSpec((cfg.padded_vocab, D), ("vocab", "embed"),
                           scale=0.02),
        "final_norm": L.norm_specs(cfg, D),
        "dec": tuple(
            tuple(stack_specs(block_specs(cfg, spec), R) for spec in pattern)
            for pattern, R in cfg.groups),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = ParamSpec((D, cfg.padded_vocab), ("embed", "vocab"))
    if cfg.pos_embed == "learned":
        p["pos_table"] = ParamSpec((cfg.max_seq, D), (None, "embed"),
                                   scale=0.02)
    if cfg.is_encdec:
        p["enc"] = {
            "groups": tuple(
                tuple(stack_specs(block_specs(cfg, spec), R)
                      for spec in pattern)
                for pattern, R in cfg.enc_groups),
            "final_norm": L.norm_specs(cfg, D),
            "pos_table": ParamSpec((cfg.enc_seq, D), (None, "embed"),
                                   scale=0.02),
        }
    return _apply_dtype(p, cfg.dtype)


def cache_specs(cfg: ModelConfig, batch: int, seq: int):
    return tuple(
        tuple(stack_specs(block_cache_specs(cfg, spec, batch, seq), R)
              for spec in pattern)
        for pattern, R in cfg.groups)


# ---------------------------------------------------------------------------
# Forward passes


def _embed_tokens(cfg, params, tokens):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    return x


def _unembed(cfg, params, x):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = x @ params["unembed"]
    logits = logits.astype(F32)
    logits = constrain(logits, "batch", "seq", "act_vocab")
    # mask vocab padding
    pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab
    logits = jnp.where(pad, -1e9, logits)
    return constrain(logits, "batch", "seq", "act_vocab")


def _encode(cfg: ModelConfig, params, enc_embeds):
    B, Se, D = enc_embeds.shape
    x = enc_embeds.astype(cfg.dtype) + params["enc"]["pos_table"][None, :Se]\
        .astype(cfg.dtype)
    pos = jnp.broadcast_to(jnp.arange(Se), (B, Se))
    ctx = L.Ctx("full", pos, None, None, None, False)
    x, _, _ = _run_groups(cfg, cfg.enc_groups, params["enc"]["groups"], x,
                          ctx, None)
    return L.norm_apply(cfg, params["enc"]["final_norm"], x)


def forward_hidden(cfg: ModelConfig, params, batch, *,
                   build_cache: bool = False, cache_len: int | None = None):
    """Teacher-forcing trunk. batch: tokens (B,S) [+ vision_embeds /
    enc_embeds]. Returns (hidden, aux, cache_or_None)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed_tokens(cfg, params, tokens)
    if cfg.n_vision_tokens:
        ve = batch["vision_embeds"].astype(cfg.dtype)
        x = lax.dynamic_update_slice(x, ve, (0, 0, 0))
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    if cfg.pos_embed == "learned":
        x = x + params["pos_table"][None, :S].astype(cfg.dtype)
    enc_out = None
    if cfg.is_encdec:
        enc_out = _encode(cfg, params, batch["enc_embeds"])
    x = constrain(x, "batch", "seq", None)
    ctx = L.Ctx("full", positions, None, cache_len, enc_out, build_cache)
    x, aux, caches = _run_groups(cfg, cfg.groups, params["dec"], x, ctx,
                                 None)
    x = L.norm_apply(cfg, params["final_norm"], x)
    return x, aux, (caches if build_cache else None)


def forward(cfg: ModelConfig, params, batch, *, build_cache: bool = False):
    x, aux, caches = forward_hidden(cfg, params, batch,
                                    build_cache=build_cache)
    return _unembed(cfg, params, x), aux, caches


def loss_fn(cfg: ModelConfig, params, batch):
    logits, aux, _ = forward(cfg, params, batch)
    labels = batch["labels"]
    # Shard-local cross-entropy: never gathers the vocab axis (the gather
    # form take_along_axis would all-gather (B,S,V) f32 per chip).
    lse = jax.nn.logsumexp(logits, axis=-1)                      # (B,S)
    vid = jnp.arange(cfg.padded_vocab, dtype=jnp.int32)
    sel = constrain(vid == labels[..., None], "batch", "seq", "act_vocab")
    picked = jnp.sum(jnp.where(sel, logits, 0.0), axis=-1)
    nll = lse - picked
    loss = nll.mean() + AUX_COEF * aux
    return loss, {"nll": nll.mean(), "aux": aux}


def prefill(cfg: ModelConfig, params, batch, cache_len: int | None = None):
    """Returns (last_token_logits, cache). Unembeds ONLY the last position —
    full-sequence logits at 32k would be ~TBs. cache_len sizes the decode
    ring buffers (defaults to the prompt length, per the dry-run shapes)."""
    x, _, cache = forward_hidden(cfg, params, batch, build_cache=True,
                                 cache_len=cache_len)
    logits = _unembed(cfg, params, x[:, -1:])
    return logits[:, -1], cache


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """One decode step. tokens: (B,1) int32; pos: scalar int32 (absolute).
    Returns (logits (B,V), new_cache)."""
    B = tokens.shape[0]
    x = _embed_tokens(cfg, params, tokens)
    positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
    if cfg.pos_embed == "learned":
        x = x + params["pos_table"][pos][None, None].astype(cfg.dtype)
    ctx = L.Ctx("decode", positions, pos, None, None, False)
    x, _, new_cache = _run_groups(cfg, cfg.groups, params["dec"], x, ctx,
                                  cache)
    x = L.norm_apply(cfg, params["final_norm"], x)
    logits = _unembed(cfg, params, x)
    return logits[:, 0], new_cache


# ---------------------------------------------------------------------------
# Analytics (for roofline MODEL_FLOPS)


def param_counts(cfg: ModelConfig) -> tuple[int, int]:
    """(total, active) parameter counts; active scales routed experts k/E."""
    import jax.tree_util as jtu
    from repro.models.params import is_spec
    specs = model_specs(cfg)
    total = active = 0
    for path, s in jtu.tree_flatten_with_path(specs, is_leaf=is_spec)[0]:
        n = 1
        for d in s.shape:
            n *= d
        total += n
        name = "/".join(str(p) for p in path)
        if "moe" in name and ("'w1'" in name or "'w2'" in name
                              or "'w3'" in name):
            active += n * cfg.top_k // max(cfg.n_experts, 1)
        else:
            active += n
    return total, active


def model_flops(cfg: ModelConfig, kind: str, seq: int, batch: int) -> float:
    """6·N_active·tokens (train) or 2·N_active·tokens (inference)."""
    _, active = param_counts(cfg)
    tokens = seq * batch if kind in ("train", "prefill") else batch
    mult = 6 if kind == "train" else 2
    return float(mult) * active * tokens

"""Model substrate: norms, rope, attention (GQA/blockwise/MLA), MoE, Mamba2.

Every layer exposes
  ``<layer>_specs(cfg, spec) -> pytree[ParamSpec]``
  ``<layer>_apply(cfg, spec, params, x, ctx) -> y``  (pure function)
so the dry-run can build ShapeDtypeStructs and shardings from the same
source of truth as initialization.

Attention has two mathematically-identical implementations:
  - ``naive``: materializes (Sq, Skv) scores — fine for short seq;
  - ``blockwise``: online-softmax scan over KV chunks (the jnp twin of the
    Pallas flash kernel in ``repro.kernels.flash_attention``) — required for
    32k+ prefill so compiled HBM usage stays linear in S.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import LayerSpec, ModelConfig
from repro.models.params import ParamSpec
from repro.parallel.sharding import constrain, shard_map

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Context threaded through layers


class Ctx(NamedTuple):
    mode: str                  # "full" (train/prefill) | "decode"
    positions: jax.Array       # (B, S) int32 absolute positions
    pos: jax.Array | None      # scalar int32 — decode write offset
    cache_len: int | None      # cache buffer capacity (prefill allocation)
    enc_out: jax.Array | None  # encoder states for cross-attention
    build_cache: bool = False  # prefill: emit cache entries


def _ring_place(k: jax.Array, W: int) -> jax.Array:
    """Scatter the last min(S, W) tokens of k (B,S,...) into a W-slot ring
    buffer at slot (absolute_position % W) — the layout decode's
    `pos % W` insertion expects."""
    B, S = k.shape[:2]
    n = min(S, W)
    pos0 = S - n
    idx = (pos0 + jnp.arange(n)) % W
    buf = jnp.zeros((B, W) + k.shape[2:], k.dtype)
    return buf.at[:, idx].set(k[:, S - n:])


def _ring_valid_mask(pos, W: int) -> jax.Array:
    """Additive mask (W,) — slots beyond min(pos+1, W) hold no token."""
    valid = jnp.arange(W) < jnp.minimum(pos + 1, W)
    return jnp.where(valid, 0.0, -jnp.inf).astype(F32)


# ---------------------------------------------------------------------------
# Norms


def norm_specs(cfg: ModelConfig, d: int):
    p = {"scale": ParamSpec((d,), (None,), "ones", dtype=F32)}
    if cfg.norm == "layernorm":
        p["bias"] = ParamSpec((d,), (None,), "zeros", dtype=F32)
    return p


def norm_apply(cfg: ModelConfig, params, x):
    xf = x.astype(F32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * lax.rsqrt(var + cfg.norm_eps)
        y = y * params["scale"] + params["bias"]
    else:
        var = (xf * xf).mean(-1, keepdims=True)
        y = xf * lax.rsqrt(var + cfg.norm_eps) * params["scale"]
    return y.astype(x.dtype)


def _rms(x, scale, eps):
    xf = x.astype(F32)
    y = xf * lax.rsqrt((xf * xf).mean(-1, keepdims=True) + eps) * scale
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, d) rotated at `positions` (broadcastable to (..., S))."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=F32) / half)
    ang = positions[..., None].astype(F32) * freq          # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(F32), x[..., half:].astype(F32)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Core attention math (shared by naive / blockwise / decode)


def _mask(q_pos, k_pos, *, causal: bool, window: int | None, valid_len=None):
    """q_pos: (..., Sq), k_pos: (Skv,) — returns additive mask (..., Sq, Skv)."""
    m = jnp.zeros(q_pos.shape + (k_pos.shape[-1],), F32)
    qp = q_pos[..., None].astype(jnp.int32)
    kp = k_pos.astype(jnp.int32)
    ok = jnp.ones_like(m, dtype=bool)
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    if valid_len is not None:
        ok &= kp < valid_len
    return jnp.where(ok, m, -jnp.inf)


def _sdpa(q, k, v, mask):
    """q: (B,Sq,K,R,hd), k/v: (B,Skv,K,hd), mask: (B?,Sq,Skv) additive.
    Grouped layout — used on the decode path (Sq=1, cache possibly
    seq-sharded so scores reduce over the sharded axis)."""
    hd = q.shape[-1]
    s = jnp.einsum("bqkrd,bskd->bkrqs", q.astype(F32), k.astype(F32))
    s = s * (hd ** -0.5) + mask[..., None, None, :, :]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkrqs,bskd->bqkrd", p, v.astype(F32))
    return o.astype(q.dtype)


def _sdpa_h(q, k, v, mask):
    """H-layout full attention: q (B,Sq,H,hd), k/v (B,Skv,H,hd) pre-repeated
    so the head axis shards on `model`."""
    hd = q.shape[-1]
    s = jnp.einsum("bqhd,bshd->bhqs", q.astype(F32), k.astype(F32))
    s = s * (hd ** -0.5) + mask[:, None, :, :]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqs,bshd->bqhd", p, v.astype(F32))
    return o.astype(q.dtype)


@functools.lru_cache(maxsize=None)
def _flash_fn(causal: bool, window: int | None, kv_chunk: int):
    """Flash attention (jnp twin of kernels/flash_attention) as a custom_vjp.

    H-layout: q (B,Sq,H,hd), k/v (B,Skv,H,hd_k/hd_v) — GQA callers repeat KV
    heads first (cheap; shards on the head axis). Saves only (q,k,v,o,lse);
    the backward recomputes scores chunk-by-chunk — nothing O(Sq*Skv) is ever
    live or stacked across scan steps.
    """

    def _chunks(x, nk, c):
        B, S, H, d = x.shape
        return jnp.moveaxis(x.reshape(B, nk, c, H, d), 1, 0)

    def fwd_scan(q, k, v):
        B, Sq, H, hd = q.shape
        Skv = k.shape[1]
        nk = max(Skv // kv_chunk, 1)
        c = Skv // nk
        qf = q.astype(F32) * (hd ** -0.5)
        q_pos = jnp.arange(Sq)

        def body(carry, xs):
            acc, m, l = carry
            k_blk, v_blk, k0 = xs
            kp = k0 + jnp.arange(c)
            s = jnp.einsum("bqhd,bshd->bhqs", qf, k_blk.astype(F32))
            s = s + _mask(q_pos, kp, causal=causal, window=window)[
                None, None, :, :]
            m_new = jnp.maximum(m, s.max(-1))
            # fully-masked (row, chunk) pairs keep m_new == -inf; clamp the
            # subtrahend so exp(-inf - finite) = 0 instead of exp(nan).
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            corr = jnp.exp(jnp.where(jnp.isfinite(m), m, -jnp.inf) - m_safe)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqs,bshd->bhqd", p, v_blk.astype(F32))
            return (acc, m_new, l), None

        acc0 = jnp.zeros((B, H, Sq, v.shape[-1]), F32)
        m0 = jnp.full((B, H, Sq), -jnp.inf, F32)
        l0 = jnp.zeros((B, H, Sq), F32)
        (acc, m, l), _ = lax.scan(
            body, (acc0, m0, l0),
            (_chunks(k, nk, c), _chunks(v, nk, c), jnp.arange(nk) * c))
        o = (acc / jnp.maximum(l, 1e-37)[..., None])
        lse = jnp.where(jnp.isfinite(m), m + jnp.log(jnp.maximum(l, 1e-37)),
                        jnp.inf)                       # (B,H,Sq)
        return o, lse

    @jax.custom_vjp
    def flash(q, k, v):
        o, _ = fwd_scan(q, k, v)
        return jnp.moveaxis(o, 1, 2).astype(q.dtype)   # (B,Sq,H,hd_v)

    def flash_fwd(q, k, v):
        o, lse = fwd_scan(q, k, v)
        out = jnp.moveaxis(o, 1, 2).astype(q.dtype)
        return out, (q, k, v, o, lse)

    def flash_bwd(res, do):
        q, k, v, o, lse = res
        B, Sq, H, hd = q.shape
        Skv = k.shape[1]
        nk = max(Skv // kv_chunk, 1)
        c = Skv // nk
        sc = hd ** -0.5
        qf = q.astype(F32)
        dof = jnp.moveaxis(do.astype(F32), 1, 2)       # (B,H,Sq,hd_v)
        Drow = jnp.sum(dof * o, axis=-1)               # (B,H,Sq)
        q_pos = jnp.arange(Sq)

        def body(dq, xs):
            k_blk, v_blk, k0 = xs
            kp = k0 + jnp.arange(c)
            s = jnp.einsum("bqhd,bshd->bhqs", qf, k_blk.astype(F32)) * sc
            s = s + _mask(q_pos, kp, causal=causal, window=window)[
                None, None, :, :]
            p = jnp.exp(s - lse[..., None])            # (B,H,Sq,c)
            dv_j = jnp.einsum("bhqs,bhqd->bshd", p, dof)
            dp = jnp.einsum("bhqd,bshd->bhqs", dof, v_blk.astype(F32))
            ds = p * (dp - Drow[..., None]) * sc
            dq = dq + jnp.einsum("bhqs,bshd->bqhd", ds, k_blk.astype(F32))
            dk_j = jnp.einsum("bhqs,bqhd->bshd", ds, qf)
            return dq, (dk_j, dv_j)

        dq0 = jnp.zeros((B, Sq, H, hd), F32)
        dq, (dks, dvs) = lax.scan(
            body, dq0,
            (_chunks(k, nk, c), _chunks(v, nk, c), jnp.arange(nk) * c))
        dk = jnp.moveaxis(dks, 0, 1).reshape(k.shape)
        dv = jnp.moveaxis(dvs, 0, 1).reshape(v.shape)
        return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)

    flash.defvjp(flash_fwd, flash_bwd)
    return flash


def banded_sdpa(q, k, v, *, window: int, q_chunk: int):
    """Band-limited causal attention for sliding-window layers (beyond-
    paper opt, cfg.banded_window_attn): each q chunk attends only its
    [q0-window, q0+q_chunk) key band — O(S*(window+q_chunk)) FLOPs instead
    of the flash path's masked O(S^2).

    q: (B,S,K,R,hd) grouped; k/v: (B,Skv,K,hd).
    """
    B, S, K, R, hd = q.shape
    H = K * R
    qh = q.reshape(B, S, H, hd)
    k_rep = constrain(jnp.repeat(k, R, axis=2), "batch", "seq", "act_heads",
                      None)
    v_rep = constrain(jnp.repeat(v, R, axis=2), "batch", "seq", "act_heads",
                      None)
    qc = min(q_chunk, S)
    band = min(window + qc, S)
    nq = S // qc
    qf = (qh.astype(F32) * hd ** -0.5).reshape(B, nq, qc, H, hd)

    def chunk(_, xs):
        qi, q_blk = xs
        start = jnp.clip(qi * qc - window, 0, S - band)
        k_band = lax.dynamic_slice(k_rep, (0, start, 0, 0),
                                   (B, band, H, hd)).astype(F32)
        v_band = lax.dynamic_slice(v_rep, (0, start, 0, 0),
                                   (B, band, H, hd)).astype(F32)
        q_pos = qi * qc + jnp.arange(qc)
        k_pos = start + jnp.arange(band)
        s = jnp.einsum("bqhd,bshd->bhqs", q_blk, k_band)
        s = s + _mask(q_pos, k_pos, causal=True, window=window)[
            None, None, :, :]
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqs,bshd->bqhd", p, v_band)
        return None, o

    _, outs = lax.scan(chunk, None,
                       (jnp.arange(nq), jnp.moveaxis(qf, 1, 0)))
    o = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd).astype(q.dtype)
    return o.reshape(B, S, K, R, hd)


def blockwise_sdpa(q, k, v, q_pos, *, causal, window, kv_chunk,
                   kv_pos0: int = 0):
    """Flash attention over KV chunks, H layout with grouped-KV input.

    q: (B,Sq,K,R,hd); k/v: (B,Skv,K,hd). Positions must be arange (full
    forward/prefill only — decode uses the naive path over the cache).
    """
    B, Sq, K, R, hd = q.shape
    qh = q.reshape(B, Sq, K * R, hd)
    k_rep = jnp.repeat(k, R, axis=2)
    v_rep = jnp.repeat(v, R, axis=2)
    qh = constrain(qh, "batch", "seq", "act_heads", None)
    k_rep = constrain(k_rep, "batch", "seq", "act_heads", None)
    v_rep = constrain(v_rep, "batch", "seq", "act_heads", None)
    o = _flash_fn(bool(causal), window, int(kv_chunk))(qh, k_rep, v_rep)
    return o.reshape(B, Sq, K, R, o.shape[-1])


# ---------------------------------------------------------------------------
# GQA attention layer


def attn_specs(cfg: ModelConfig, spec: LayerSpec):
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    kv_src = cfg.d_model
    p = {
        "wq": ParamSpec((D, H, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((kv_src, K, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((kv_src, K, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((H, hd, D), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamSpec((H, hd), ("heads", "head_dim"), "zeros")
        p["bk"] = ParamSpec((K, hd), ("kv_heads", "head_dim"), "zeros")
        p["bv"] = ParamSpec((K, hd), ("kv_heads", "head_dim"), "zeros")
    if cfg.qk_norm:
        p["qn"] = ParamSpec((hd,), (None,), "ones", dtype=F32)
        p["kn"] = ParamSpec((hd,), (None,), "ones", dtype=F32)
    return p


def attn_cache_specs(cfg: ModelConfig, spec: LayerSpec, batch: int, seq: int,
                     allow_int8: bool = True):
    W = min(seq, spec.window) if spec.window else seq
    K, hd = cfg.n_kv_heads, cfg.head_dim
    ax = ("cache_batch", "cache_seq", "cache_heads", "head_dim")
    if cfg.kv_cache_int8 and allow_int8:
        # int8 payload + per-(token, head) f32 scales: halves the HBM read
        # per decode step vs bf16 (the dominant decode cost)
        sax = ("cache_batch", "cache_seq", "cache_heads")
        return {"k": ParamSpec((batch, W, K, hd), ax, "zeros",
                               dtype=jnp.int8),
                "v": ParamSpec((batch, W, K, hd), ax, "zeros",
                               dtype=jnp.int8),
                "ks": ParamSpec((batch, W, K), sax, "zeros", dtype=F32),
                "vs": ParamSpec((batch, W, K), sax, "zeros", dtype=F32)}
    return {"k": ParamSpec((batch, W, K, hd), ax, "zeros", dtype=cfg.dtype),
            "v": ParamSpec((batch, W, K, hd), ax, "zeros", dtype=cfg.dtype)}


def _kv_quant(x):
    """x: (B, S, K, hd) -> (int8 payload, (B,S,K) f32 scales)."""
    s = jnp.max(jnp.abs(x.astype(F32)), axis=-1) / 127.0
    s = jnp.maximum(s, 1e-12)
    q = jnp.clip(jnp.round(x.astype(F32) / s[..., None]), -127, 127)
    return q.astype(jnp.int8), s


def _kv_dequant(q, s, dtype):
    return (q.astype(F32) * s[..., None]).astype(dtype)


def attn_apply(cfg: ModelConfig, spec: LayerSpec, params, x, ctx: Ctx,
               cache=None):
    """Returns (y, new_cache_or_None)."""
    B, S, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    R = H // K
    theta = spec.rope_theta or cfg.rope_theta
    cross = spec.cross_attn

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if cfg.qkv_bias:
        q = q + params["bq"]
    if cross:
        src = ctx.enc_out
        k = jnp.einsum("bsd,dhk->bshk", src, params["wk"]) if src is not None \
            else None
        v = jnp.einsum("bsd,dhk->bshk", src, params["wv"]) if src is not None \
            else None
    else:
        k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias and k is not None:
        k, v = k + params["bk"], v + params["bv"]
    if cfg.qk_norm:
        q = _rms(q, params["qn"], cfg.norm_eps)
        if k is not None:
            k = _rms(k, params["kn"], cfg.norm_eps)
    if cfg.pos_embed == "rope" and not cross:
        q = rope(q, ctx.positions, theta)
        if k is not None:
            k = rope(k, ctx.positions, theta)
    q = constrain(q, "batch", "seq", "act_heads", None)
    q = q.reshape(B, S, K, R, hd)

    new_cache = None
    if ctx.mode == "decode":
        if cross:
            ck, cv = cache["k"], cache["v"]          # cross-cache, static
            new_cache = cache
            kp0 = 0
            mask = _mask(ctx.positions, jnp.arange(ck.shape[1]) + kp0,
                         causal=False, window=None)
            o = _sdpa(q, ck, cv, mask)
        else:
            Wbuf = cache["k"].shape[1]
            slot = (ctx.pos % Wbuf).astype(jnp.int32)
            if cfg.kv_cache_int8:
                kq, ks = _kv_quant(k)
                vq, vs = _kv_quant(v)
                cki = lax.dynamic_update_slice(cache["k"], kq,
                                               (0, slot, 0, 0))
                cvi = lax.dynamic_update_slice(cache["v"], vq,
                                               (0, slot, 0, 0))
                cks = lax.dynamic_update_slice(cache["ks"], ks, (0, slot, 0))
                cvs = lax.dynamic_update_slice(cache["vs"], vs, (0, slot, 0))
                new_cache = {"k": cki, "v": cvi, "ks": cks, "vs": cvs}
                ck = _kv_dequant(cki, cks, cfg.dtype)
                cv = _kv_dequant(cvi, cvs, cfg.dtype)
            else:
                ck = lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
                cv = lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
                new_cache = {"k": ck, "v": cv}
            # Ring buffer: slots past min(pos+1, W) hold nothing yet.
            mask = jnp.broadcast_to(_ring_valid_mask(ctx.pos, Wbuf),
                                    (B, S, Wbuf))
            o = _sdpa(q, ck, cv, mask)
    else:
        if cross:
            mask = _mask(ctx.positions,
                         jnp.arange(k.shape[1]), causal=False, window=None)
            o = _sdpa(q, k, v, mask)
            if ctx.build_cache:
                new_cache = {"k": k, "v": v}
        else:
            use_banded = (cfg.banded_window_attn and spec.window
                          and spec.causal
                          and S >= 2 * (spec.window + cfg.q_chunk))
            use_blockwise = (cfg.attn_impl == "blockwise" or
                             (cfg.attn_impl == "auto" and
                              S > cfg.blockwise_min_seq))
            if use_banded:
                o = banded_sdpa(q, k, v, window=spec.window,
                                q_chunk=cfg.q_chunk)
            elif use_blockwise:
                o = blockwise_sdpa(q, k, v, ctx.positions, causal=spec.causal,
                                   window=spec.window,
                                   kv_chunk=min(cfg.kv_chunk, S))
            else:
                mask = _mask(ctx.positions, jnp.arange(S), causal=spec.causal,
                             window=spec.window)
                qh = q.reshape(B, S, H, hd)
                k_rep = constrain(jnp.repeat(k, R, axis=2),
                                  "batch", "seq", "act_heads", None)
                v_rep = constrain(jnp.repeat(v, R, axis=2),
                                  "batch", "seq", "act_heads", None)
                o = _sdpa_h(qh, k_rep, v_rep, mask).reshape(B, S, K, R, hd)
            if ctx.build_cache:
                cap = ctx.cache_len or S
                W = min(spec.window, cap) if spec.window else cap
                kr_, vr_ = _ring_place(k, W), _ring_place(v, W)
                if cfg.kv_cache_int8:
                    kq, ks = _kv_quant(kr_)
                    vq, vs = _kv_quant(vr_)
                    new_cache = {"k": kq, "v": vq, "ks": ks, "vs": vs}
                else:
                    new_cache = {"k": kr_, "v": vr_}
    o = o.reshape(B, S, H, hd)
    y = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
    return constrain(y, "batch", "seq", None), new_cache


# ---------------------------------------------------------------------------
# MLA attention (MiniCPM3 / DeepSeek-V2 style)


def _mla_heads(cfg: ModelConfig) -> int:
    """Optionally pad MLA head count for TP shardability (e.g. 40 -> 48 on
    a 16-way model axis). Padded heads are inert at zero wo rows; the win
    is that attention compute shards instead of replicating 16x."""
    if cfg.pad_heads_to and cfg.pad_heads_to > cfg.n_heads:
        return cfg.pad_heads_to
    return cfg.n_heads


def mla_specs(cfg: ModelConfig, spec: LayerSpec):
    D, H = cfg.d_model, _mla_heads(cfg)
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wdq": ParamSpec((D, qr), ("embed", "mla_rank")),
        "q_norm": ParamSpec((qr,), (None,), "ones", dtype=F32),
        "wuq": ParamSpec((qr, H, dn + dr), ("mla_rank", "heads", None)),
        "wdkv": ParamSpec((D, kr + dr), ("embed", None)),
        "kv_norm": ParamSpec((kr,), (None,), "ones", dtype=F32),
        "wuk": ParamSpec((kr, H, dn), ("mla_rank", "heads", None)),
        "wuv": ParamSpec((kr, H, dv), ("mla_rank", "heads", None)),
        "wo": ParamSpec((H, dv, D), ("heads", None, "embed")),
    }


def mla_cache_specs(cfg: ModelConfig, spec: LayerSpec, batch: int, seq: int):
    kr, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    return {
        "ckv": ParamSpec((batch, seq, kr), ("cache_batch", "cache_seq", None),
                         "zeros", dtype=cfg.dtype),
        "kr": ParamSpec((batch, seq, dr), ("cache_batch", "cache_seq", None),
                        "zeros", dtype=cfg.dtype),
    }


def mla_apply(cfg: ModelConfig, spec: LayerSpec, params, x, ctx: Ctx,
              cache=None):
    B, S, D = x.shape
    H = _mla_heads(cfg)
    kr, dn, dr, dv = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                      cfg.v_head_dim)
    sc = (dn + dr) ** -0.5

    cq = _rms(x @ params["wdq"], params["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, params["wuq"])   # (B,S,H,dn+dr)
    qn, qr_ = q[..., :dn], rope(q[..., dn:], ctx.positions, cfg.rope_theta)

    dkv = x @ params["wdkv"]                             # (B,S,kr+dr)
    ckv = _rms(dkv[..., :kr], params["kv_norm"], cfg.norm_eps)
    krope = rope(dkv[..., None, kr:], ctx.positions, cfg.rope_theta)[:, :, 0]

    new_cache = None
    if ctx.mode == "decode":
        Wbuf = cache["ckv"].shape[1]
        slot = (ctx.pos % Wbuf).astype(jnp.int32)
        ckv = lax.dynamic_update_slice(cache["ckv"], ckv, (0, slot, 0))
        krope = lax.dynamic_update_slice(cache["kr"], krope, (0, slot, 0))
        new_cache = {"ckv": ckv, "kr": krope}
    elif ctx.build_cache:
        cap = ctx.cache_len or S
        new_cache = {"ckv": _ring_place(ckv, cap),
                     "kr": _ring_place(krope, cap)}

    if ctx.mode == "decode":
        # Absorbed form: score/value in rank space — cache stays compressed.
        q_c = jnp.einsum("bshk,rhk->bshr", qn.astype(F32),
                         params["wuk"].astype(F32))      # (B,S,H,kr)
        s = (jnp.einsum("bshr,btr->bhst", q_c, ckv.astype(F32)) +
             jnp.einsum("bshk,btk->bhst", qr_.astype(F32),
                        krope.astype(F32))) * sc
        s = s + _ring_valid_mask(ctx.pos, s.shape[-1])
        p = jax.nn.softmax(s, axis=-1)
        o_c = jnp.einsum("bhst,btr->bshr", p, ckv.astype(F32))
        o = jnp.einsum("bshr,rhk->bshk", o_c,
                       params["wuv"].astype(F32)).astype(x.dtype)
    else:
        # Expanded form for training/prefill.
        kn = jnp.einsum("btr,rhk->bthk", ckv, params["wuk"])
        v = jnp.einsum("btr,rhk->bthk", ckv, params["wuv"])
        kfull = jnp.concatenate(
            [kn, jnp.broadcast_to(krope[:, :, None, :], kn.shape[:3] + (dr,))],
            axis=-1)
        qfull = jnp.concatenate([qn, qr_], axis=-1)
        qg = qfull.reshape(B, S, H, 1, dn + dr)          # GQA layout, R=1
        if cfg.attn_impl != "naive" and S > cfg.blockwise_min_seq:
            o = blockwise_sdpa(qg, kfull, v, ctx.positions, causal=True,
                               window=None, kv_chunk=min(cfg.kv_chunk, S))
        else:
            mask = _mask(ctx.positions, jnp.arange(S), causal=True,
                         window=None)
            o = _sdpa(qg, kfull, v, mask)
        o = o.reshape(B, S, H, dv)  # attention output carries v_head_dim
    y = jnp.einsum("bshk,hkd->bsd", o.reshape(B, S, H, dv), params["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU) and MoE


def mlp_specs(cfg: ModelConfig, d_ff: int | None = None):
    D, Fw = cfg.d_model, d_ff or cfg.d_ff
    return {"w1": ParamSpec((D, Fw), ("embed", "ffn")),
            "w3": ParamSpec((D, Fw), ("embed", "ffn")),
            "w2": ParamSpec((Fw, D), ("ffn", "embed"))}


def mlp_apply(cfg: ModelConfig, params, x):
    h = jax.nn.silu(x @ params["w1"]) * (x @ params["w3"])
    h = constrain(h, "batch", "seq", "act_ffn")
    return h @ params["w2"]


def _moe_experts(cfg: ModelConfig) -> int:
    """Optionally pad expert count for expert parallelism (e.g. 60 -> 64 on
    a 16-way model axis): padded experts are never routed to; the win is
    that expert compute and dispatch buffers shard on the expert dim, so
    the w2 partial-sum all-reduce shrinks from (E,C)-space to token space.
    """
    if cfg.pad_experts_to and cfg.pad_experts_to > cfg.n_experts:
        return cfg.pad_experts_to
    return cfg.n_experts


def moe_specs(cfg: ModelConfig, spec: LayerSpec):
    D, E, Fe = cfg.d_model, cfg.n_experts, cfg.d_expert or cfg.d_ff
    Ep = _moe_experts(cfg)
    p = {
        "router": ParamSpec((D, E), ("embed", None), dtype=F32),
        "w1": ParamSpec((Ep, D, Fe), ("experts", "embed", "expert_ffn")),
        "w3": ParamSpec((Ep, D, Fe), ("experts", "embed", "expert_ffn")),
        "w2": ParamSpec((Ep, Fe, D), ("experts", "expert_ffn", "embed")),
    }
    if cfg.d_shared:
        p["shared"] = mlp_specs(cfg, cfg.d_shared)
        p["shared_gate"] = ParamSpec((D, 1), ("embed", None), dtype=F32)
    return p


def _moe_expert_compute(params, buf):
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, params["w1"])) * \
        jnp.einsum("becd,edf->becf", buf, params["w3"])
    return jnp.einsum("becf,efd->becd", h, params["w2"])


def moe_apply_ep(cfg: ModelConfig, params, x, gate, idx, pos_c, keep, C):
    """Expert-parallel dispatch (beyond-paper opt, cfg.pad_experts_to):
    shard_map over the model axis — each shard owns Ep/|model| experts,
    scatters only its tokens, computes locally, and contributes a partial
    token-space output; one (B,S,D) psum replaces the baseline's
    (E,C,D)-space all-reduce."""
    from jax.sharding import PartitionSpec as P
    from repro.parallel.sharding import _get_mesh
    mesh = _get_mesh()
    B, S, D = x.shape
    Ep = _moe_experts(cfg)
    nshard = mesh.shape["model"]
    epp = Ep // nshard
    bidx = jnp.broadcast_to(jnp.arange(B)[:, None, None], idx.shape)

    cdt = x.dtype

    def body(x, gate, idx, pos_c, keep, sid, w1, w3, w2):
        # shard id via sharded-iota input: lax.axis_index would lower to
        # partition-id, which SPMD partitioning of the auto axes rejects
        m = sid[0]
        x, w1, w3, w2 = (a.astype(cdt) for a in (x, w1, w3, w2))
        local = keep & (idx >= m * epp) & (idx < (m + 1) * epp)
        idx_l = jnp.where(local, idx - m * epp, 0)
        upd = jnp.where(local[..., None], x[:, :, None, :], 0)
        buf = jnp.zeros((B, epp, C, D), x.dtype)
        buf = buf.at[bidx, idx_l, pos_c].add(upd.astype(x.dtype))
        y_buf = _moe_expert_compute({"w1": w1, "w3": w3, "w2": w2}, buf)
        y_tok = y_buf[bidx, idx_l, pos_c] * local[..., None]
        y = (y_tok * (gate.astype(cdt) * keep)[..., None]
             .astype(y_tok.dtype)).sum(2)
        # psum in compute dtype (halves the ring bytes); f32 only at the
        # boundary, where this XLA build requires it
        return lax.psum(y.astype(cdt), "model").astype(F32)

    f = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), P(), P(), P(), P("model"), P("model"),
                  P("model"), P("model")),
        out_specs=P(), axis_names={"model"}, check_vma=False)
    sid = jnp.arange(nshard, dtype=jnp.int32)
    # f32 at the boundary: bf16 cotangents through a shard_map boundary
    # CHECK-crash this XLA build ("Invalid binary instruction opcode copy")
    return f(x.astype(F32), gate, idx, pos_c, keep, sid,
             params["w1"].astype(F32), params["w3"].astype(F32),
             params["w2"].astype(F32)).astype(cdt)


def moe_apply(cfg: ModelConfig, spec: LayerSpec, params, x, ctx: Ctx):
    """Token-choice top-k with per-row capacity; returns (y, aux_loss)."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = max(int(cfg.capacity_factor * S * k / E), 1)
    C = min(C, S * k)

    logits = (x @ params["router"].astype(x.dtype)).astype(F32)  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = lax.top_k(probs, k)                       # (B,S,k)
    if cfg.name.startswith("mixtral") or cfg.name.startswith("jamba"):
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # position of each (token, slot) in its expert's queue, per batch row
    oh = jax.nn.one_hot(idx, E, dtype=jnp.int32)          # (B,S,k,E)
    ohf = oh.reshape(B, S * k, E)
    pos = jnp.cumsum(ohf, axis=1) - ohf                   # exclusive prefix
    pos = (pos * ohf).sum(-1).reshape(B, S, k)            # (B,S,k)
    keep = pos < C
    pos_c = jnp.where(keep, pos, 0)

    from repro.parallel.sharding import _get_mesh
    if cfg.pad_experts_to and _get_mesh() is not None:
        y = moe_apply_ep(cfg, params, x, gate, idx, pos_c, keep, C)
    else:
        # dispatch: buf[b, e, c] = x[b, s]  (dropped tokens contribute
        # nothing; padded experts — see _moe_experts — are never indexed)
        Ep = _moe_experts(cfg)
        bidx = jnp.broadcast_to(jnp.arange(B)[:, None, None], idx.shape)
        buf = jnp.zeros((B, Ep, C, D), x.dtype)
        upd = jnp.where(keep[..., None], x[:, :, None, :], 0).astype(x.dtype)
        buf = buf.at[bidx, idx, pos_c].add(upd.reshape(B, S, k, D)[..., :])
        buf = constrain(buf, "batch", "experts", None, None)
        y_buf = _moe_expert_compute(params, buf)
        y_buf = constrain(y_buf, "batch", "experts", None, None)
        y_tok = y_buf[bidx, idx, pos_c]                   # (B,S,k,D)
        y = (y_tok * (gate * keep)[..., None].astype(y_tok.dtype)).sum(2)

    if cfg.d_shared:
        sg = jax.nn.sigmoid((x @ params["shared_gate"].astype(x.dtype))
                            .astype(F32)).astype(x.dtype)
        y = y + sg * mlp_apply(cfg, params["shared"], x)

    # Switch-style load-balance aux loss
    me = probs.mean(axis=(0, 1))                          # (E,)
    ce = (oh.sum(2).reshape(B * S, E).astype(F32)).mean(0) / k
    aux = E * jnp.sum(me * ce)
    return y, aux


# ---------------------------------------------------------------------------
# Mamba2 (SSD) mixer


def mamba2_specs(cfg: ModelConfig, spec: LayerSpec):
    D = cfg.d_model
    din, N, Hs = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = din + 2 * N
    zxbcdt = 2 * din + 2 * N + Hs
    return {
        "in_proj": ParamSpec((D, zxbcdt), ("embed", "act_ssm_inner")),
        "conv_w": ParamSpec((cfg.ssm_conv, conv_dim), ("conv", "ssm_inner")),
        "conv_b": ParamSpec((conv_dim,), ("ssm_inner",), "zeros"),
        "A_log": ParamSpec((Hs,), ("ssm_heads",), "zeros", dtype=F32),
        "D": ParamSpec((Hs,), ("ssm_heads",), "ones", dtype=F32),
        "dt_bias": ParamSpec((Hs,), ("ssm_heads",), "zeros", dtype=F32),
        "norm": ParamSpec((din,), ("ssm_inner",), "ones", dtype=F32),
        "out_proj": ParamSpec((din, D), ("ssm_inner", "embed")),
    }


def mamba2_cache_specs(cfg: ModelConfig, spec: LayerSpec, batch: int,
                       seq: int):
    din, N = cfg.d_inner, cfg.ssm_state
    Hs, P = cfg.ssm_heads, cfg.ssm_head_dim
    return {
        "h": ParamSpec((batch, Hs, P, N),
                       ("cache_batch", "ssm_heads", None, None), "zeros",
                       dtype=F32),
        "conv": ParamSpec((batch, cfg.ssm_conv - 1, din + 2 * N),
                          ("cache_batch", None, "ssm_inner"), "zeros",
                          dtype=cfg.dtype),
    }


def _segsum(x):
    """x: (..., L) -> (..., L, L) lower-tri pairwise segment sums."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, d, -jnp.inf)


def _ssd_chunked(xh, dt, a, b, c, chunk, h0=None):
    """SSD (state-space duality) chunked scan.

    xh: (B,S,H,P) inputs; dt: (B,S,H) >0; a: (H,) <0; b,c: (B,S,N).
    Returns y: (B,S,H,P), h_final: (B,H,P,N).
    """
    B, S, H, P = xh.shape
    N = b.shape[-1]
    nc = S // chunk
    L = chunk
    dA = (dt * a).reshape(B, nc, L, H)                    # log-decay per step
    xd = (xh * dt[..., None]).reshape(B, nc, L, H, P)     # dt-discretized in
    bc = b.reshape(B, nc, L, N)
    cc = c.reshape(B, nc, L, N)
    dA_cs = jnp.cumsum(dA, axis=2)                        # (B,nc,L,H)

    # intra-chunk (diagonal blocks)
    Lmat = jnp.exp(_segsum(jnp.moveaxis(dA, 3, 2)))       # (B,nc,H,L,L)
    att = jnp.einsum("bcln,bcmn->bclm", cc, bc)           # (B,nc,L,L)
    y_d = jnp.einsum("bchlm,bclm,bcmhp->bclhp",
                     Lmat, att, xd.astype(F32))

    # per-chunk terminal states
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)   # (B,nc,L,H)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn",
                        bc, decay_states, xd.astype(F32))  # (B,nc,H,P,N)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])             # (B,nc,H)
    def scan_body(h, xs):
        st, dec = xs
        h_new = h * dec[:, :, None, None] + st
        return h_new, h
    h_init = jnp.zeros((B, H, P, N), F32) if h0 is None else h0.astype(F32)
    h_last, h_prevs = lax.scan(
        scan_body, h_init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                 # (B,nc,H,P,N)

    # contribution of carried-in state
    y_o = jnp.einsum("bcln,bchpn,bclh->bclhp",
                     cc, h_prevs, jnp.exp(dA_cs))
    y = (y_d + y_o).reshape(B, S, H, P).astype(xh.dtype)
    return y, h_last


def mamba2_apply(cfg: ModelConfig, spec: LayerSpec, params, x, ctx: Ctx,
                 cache=None):
    B, S, D = x.shape
    din, N = cfg.d_inner, cfg.ssm_state
    Hs, P = cfg.ssm_heads, cfg.ssm_head_dim
    conv_dim = din + 2 * N

    zxbcdt = x @ params["in_proj"]
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din:din + conv_dim]
    dt_raw = zxbcdt[..., din + conv_dim:]                 # (B,S,Hs)

    new_cache = None
    if ctx.mode == "decode":
        # conv ring: window = [conv_state, xbc]
        win = jnp.concatenate([cache["conv"].astype(xbc.dtype), xbc], axis=1)
        conv_out = (win * params["conv_w"].astype(win.dtype)).sum(1,
                                                                  keepdims=True)
        conv_out = conv_out + params["conv_b"].astype(win.dtype)
        xbc_c = jax.nn.silu(conv_out)                     # (B,1,conv_dim)
        new_conv = win[:, 1:, :]
    else:
        pad = jnp.pad(xbc, ((0, 0), (cfg.ssm_conv - 1, 0), (0, 0)))
        # depthwise causal conv via stacked shifts (d_conv is tiny: 4)
        conv_out = sum(
            pad[:, i:i + S, :] * params["conv_w"][i].astype(xbc.dtype)
            for i in range(cfg.ssm_conv))
        conv_out = conv_out + params["conv_b"].astype(xbc.dtype)
        xbc_c = jax.nn.silu(conv_out)
        new_conv = None
        if ctx.build_cache:
            new_conv = xbc[:, S - (cfg.ssm_conv - 1):, :]

    xs = xbc_c[..., :din].reshape(B, S, Hs, P)
    bmat = xbc_c[..., din:din + N]
    cmat = xbc_c[..., din + N:]
    dt = jax.nn.softplus(dt_raw.astype(F32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])                         # (Hs,) < 0

    if ctx.mode == "decode":
        h = cache["h"]
        dec = jnp.exp(dt[:, 0] * a)                       # (B,Hs)
        upd = jnp.einsum("bhp,bn->bhpn",
                         (xs[:, 0].astype(F32) * dt[:, 0][..., None]),
                         bmat[:, 0].astype(F32))
        h_new = h * dec[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0].astype(F32), h_new)
        y = y[:, None]                                    # (B,1,Hs,P)
        new_cache = {"h": h_new, "conv": new_conv.astype(cache["conv"].dtype)}
    else:
        # pad S up to a chunk multiple; dt=0 padding steps are identity for
        # the state (decay=1, zero input) and their outputs are sliced off
        chunk = min(cfg.ssm_chunk, S)
        Sp = ((S + chunk - 1) // chunk) * chunk
        if Sp != S:
            padw = ((0, 0), (0, Sp - S)) + ((0, 0),) * 10
            xs_p = jnp.pad(xs, padw[:xs.ndim])
            dt_p = jnp.pad(dt, padw[:dt.ndim])
            b_p = jnp.pad(bmat, padw[:bmat.ndim])
            c_p = jnp.pad(cmat, padw[:cmat.ndim])
        else:
            xs_p, dt_p, b_p, c_p = xs, dt, bmat, cmat
        y, h_last = _ssd_chunked(xs_p, dt_p, a, b_p, c_p, chunk, h0=None)
        y = y[:, :S]
        if ctx.build_cache:
            new_cache = {"h": h_last, "conv": new_conv}
    y = y + xs.astype(F32) * params["D"][:, None]
    y = y.reshape(B, S, din).astype(x.dtype)
    y = y * jax.nn.silu(z)                                # gated
    y = _rms(y, params["norm"], cfg.norm_eps)
    return y @ params["out_proj"], new_cache

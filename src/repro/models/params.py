"""Parameter spec system.

Modules describe their parameters once as trees of :class:`ParamSpec`
(shape + logical axes + init). From the same tree we derive
  - ``jax.ShapeDtypeStruct`` stand-ins for the dry-run (never allocated),
  - initialized arrays for smoke tests / real training,
  - ``PartitionSpec``s via ``parallel.sharding.tree_pspecs``.
"""
from __future__ import annotations

import hashlib
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class ParamSpec(NamedTuple):
    shape: tuple[int, ...]
    axes: tuple[Any, ...]            # logical axis names (len == len(shape))
    init: str = "normal"             # normal | zeros | ones | scaled
    scale: float | None = None       # stddev override for normal init
    dtype: Any = jnp.bfloat16


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_structs(spec_tree):
    """ShapeDtypeStructs for the dry-run — no device allocation."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree,
        is_leaf=is_spec)


def _leaf_key(key: jax.Array, path) -> jax.Array:
    """Deterministic per-leaf key derived from the tree path."""
    name = "/".join(str(p) for p in path)
    h = int.from_bytes(hashlib.sha256(name.encode()).digest()[:4], "little")
    return jax.random.fold_in(key, h)


def init_tree(spec_tree, key: jax.Array):
    """Materialize parameters. Normal init stddev defaults to fan-in^-1/2."""

    def one(path, s: ParamSpec):
        k = _leaf_key(key, path)
        if s.init == "zeros":
            return jnp.zeros(s.shape, s.dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, s.dtype)
        if s.init == "normal" or s.init == "scaled":
            fan_in = s.shape[0] if len(s.shape) >= 2 else max(s.shape[-1], 1)
            std = s.scale if s.scale is not None else fan_in ** -0.5
            return (jax.random.normal(k, s.shape, jnp.float32) * std).astype(s.dtype)
        raise ValueError(f"unknown init {s.init!r}")

    return jax.tree_util.tree_map_with_path(one, spec_tree, is_leaf=is_spec)


def param_count(spec_tree) -> int:
    leaves = jax.tree_util.tree_leaves(spec_tree, is_leaf=is_spec)
    n = 0
    for s in leaves:
        c = 1
        for d in s.shape:
            c *= d
        n += c
    return n


def param_bytes(spec_tree) -> int:
    leaves = jax.tree_util.tree_leaves(spec_tree, is_leaf=is_spec)
    n = 0
    for s in leaves:
        c = 1
        for d in s.shape:
            c *= d
        n += c * jnp.dtype(s.dtype).itemsize
    return n


def stack_specs(spec_tree, n: int, axis_name: str = "layers"):
    """Prepend a stacked (scan) dimension of size n to every spec."""
    return jax.tree_util.tree_map(
        lambda s: ParamSpec((n,) + s.shape, (axis_name,) + s.axes,
                            s.init, s.scale, s.dtype),
        spec_tree, is_leaf=is_spec)

"""Training launcher.

CPU-real mode (default): trains a reduced config end-to-end with
checkpoint/restart (the same loop a host process runs per node on a real
cluster, against jax.distributed instead of the in-process coord plane).

  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --steps 100

Cluster mode on TPU hosts would launch this same module once per host with
`--coordinator` set; the trainer's coordination plane (leases, membership,
shard ownership) is transport-agnostic (repro.coord).
"""
from __future__ import annotations

import argparse

from repro.configs import all_arch_names, get_config
from repro.train.loop import LoopConfig, Trainer
from repro.train.optimizer import OptConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b", choices=all_arch_names())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch-per-shard", type=int, default=2)
    ap.add_argument("--n-shards", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt_launch")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (TPU cluster scale) instead "
                         "of the reduced CPU config")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.tiny()
    loop = LoopConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir, seq_len=args.seq_len,
                      batch_per_shard=args.batch_per_shard,
                      n_shards=args.n_shards, log_every=10)
    opt = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                    total_steps=args.steps)
    tr = Trainer(cfg, opt, loop)
    state = tr.run(resume=args.resume)
    for h in tr.history:
        print(f"step {h['step']:5d}  loss {h['loss']:.4f}  "
              f"gnorm {h['grad_norm']:.2f}")
    print(f"finished at step {int(state['step'])}")


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# ^ MUST precede any jax import: device count locks at first backend init.
# The 512-device override is a *host platform* feature, so the dry run must
# pin the cpu backend — otherwise images with an accelerator runtime baked
# in (e.g. libtpu) auto-init it and the forced device count never applies.

import argparse
import gzip
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, all_arch_names, cell_supported, get_config
from repro.launch import mesh as mesh_lib
from repro.launch.hlo_analysis import analyze_program, parse_collectives
from repro.models import model as M
from repro.models.params import tree_structs
from repro.parallel import sharding as sh
from repro.train.optimizer import OptConfig, opt_state_specs
from repro.train.train_step import (make_decode_step, make_prefill_step,
                                    make_train_step)

I32 = jnp.int32
SDS = jax.ShapeDtypeStruct

# Beyond-paper optimization variants (EXPERIMENTS.md §Perf): enabled by
# --opt; the faithful baseline keeps every knob off.
OPT_OVERRIDES = {
    "minicpm3-4b": dict(pad_heads_to=48),     # 40 heads can't shard 16-way
    # EP (shard_map) requires unrolled layers: XLA-CPU CHECK-crashes on
    # grad(scan(shard_map)) — documented refuted/blocked paths in
    # EXPERIMENTS.md §Perf
    "qwen2-moe-a2.7b": dict(pad_experts_to=64, _unroll=True),
    "gemma3-1b": dict(banded_window_attn=True),
    "mixtral-8x7b": dict(banded_window_attn=True),
    "qwen2-72b": dict(kv_cache_int8=True),   # decode memory term (KV reads)
    "yi-9b": dict(kv_cache_int8=True),
}


def apply_opt(cfg):
    import dataclasses
    over = dict(OPT_OVERRIDES.get(cfg.name) or {})
    unroll = over.pop("_unroll", False)
    if over:
        cfg = dataclasses.replace(cfg, **over)
    if unroll:
        cfg = cfg.unroll()
    return cfg


def input_specs(arch: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of this cell —
    weak-type-correct, shardable, never allocated."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    D = cfg.d_model

    def batch_structs(with_labels: bool, seq: int):
        d = {"tokens": (SDS((B, seq), I32), ("batch", "seq"))}
        if with_labels:
            d["labels"] = (SDS((B, seq), I32), ("batch", "seq"))
        if cfg.n_vision_tokens:
            d["vision_embeds"] = (SDS((B, cfg.n_vision_tokens, D), cfg.dtype),
                                  ("batch", None, None))
        if cfg.is_encdec:
            d["enc_embeds"] = (SDS((B, cfg.enc_seq, D), cfg.dtype),
                               ("batch", None, None))
        return d

    if kind == "train":
        return {"batch": batch_structs(True, S)}
    if kind == "prefill":
        return {"batch": batch_structs(False, S)}
    # decode kinds: one new token against a seq_len cache
    return {
        "tokens": (SDS((B, 1), I32), ("batch", None)),
        "pos": (SDS((), I32), ()),
        "cache_batch": B, "cache_seq": S,
    }


def build_cell(arch: str, shape_name: str, mesh, opt: bool = False):
    """Returns (fn, arg_structs, in_shardings, donate)."""
    cfg = get_config(arch)
    if opt:
        cfg = apply_opt(cfg)
    shape = SHAPES[shape_name]
    kind = shape.kind
    kv_div = (cfg.n_kv_heads % mesh.shape.get("model", 1) == 0)
    rules = sh.rules_for_shape(
        "long_decode" if kind == "long_decode" else
        ("decode" if kind == "decode" else
         ("prefill" if kind == "prefill" else "train")),
        kv_divisible=kv_div)
    if cfg.pad_experts_to:
        # expert parallelism: padded expert count divides the model axis —
        # shard the expert dim (expert compute + dispatch go shard-local)
        rules = rules.override(experts="model", expert_ffn=None)

    pspecs = M.model_specs(cfg)
    p_structs = tree_structs(pspecs)
    p_shard = sh.tree_shardings(pspecs, rules, mesh)
    ins = input_specs(arch, shape_name)

    def shard_of(axes, shp):
        return sh.named_sharding(shp, axes, rules, mesh, tensor="input")

    if kind == "train":
        opt_specs = opt_state_specs(pspecs)
        o_structs = tree_structs(opt_specs)
        o_shard = sh.tree_shardings(opt_specs, rules, mesh)
        b_structs = {k: v[0] for k, v in ins["batch"].items()}
        b_shard = {k: shard_of(v[1], v[0].shape)
                   for k, v in ins["batch"].items()}
        step_s = SDS((), I32)
        fn = make_train_step(cfg, OptConfig())
        args = (p_structs, o_structs, b_structs, step_s)
        shardings = (p_shard, o_shard, b_shard,
                     sh.named_sharding((), (), rules, mesh))
        donate = (0, 1)
        return fn, args, shardings, donate, rules

    if kind == "prefill":
        b_structs = {k: v[0] for k, v in ins["batch"].items()}
        b_shard = {k: shard_of(v[1], v[0].shape)
                   for k, v in ins["batch"].items()}
        fn = make_prefill_step(cfg)
        return fn, (p_structs, b_structs), (p_shard, b_shard), (), rules

    # decode / long_decode
    c_specs = M.cache_specs(cfg, ins["cache_batch"], ins["cache_seq"])
    c_structs = tree_structs(c_specs)
    c_shard = sh.tree_shardings(c_specs, rules, mesh)
    t_struct, t_axes = ins["tokens"]
    fn = make_decode_step(cfg)
    args = (p_structs, c_structs, t_struct, SDS((), I32))
    shardings = (p_shard, c_shard, shard_of(t_axes, t_struct.shape),
                 sh.named_sharding((), (), rules, mesh))
    return fn, args, shardings, (1,), rules


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             save_hlo: bool = False, opt: bool = False) -> dict:
    t0 = time.time()
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    if opt:
        cfg = apply_opt(cfg)
    multi = mesh_kind == "multi"
    mesh = mesh_lib.make_production_mesh(multi_pod=multi)
    chips = mesh.size
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "chips": chips, "kind": shape.kind, "variant":
           "opt" if opt else "baseline"}
    ok, why = cell_supported(arch, shape_name)
    if not ok:
        rec.update(status="skip", reason=why)
        return rec
    try:
        sh.AUDIT.events.clear()
        fn, args, shardings, donate, rules = build_cell(arch, shape_name,
                                                        mesh, opt=opt)
        with mesh, sh.sharding_ctx(mesh, rules):
            jitted = jax.jit(fn, in_shardings=shardings,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            ca = compiled.cost_analysis() or {}
            if isinstance(ca, (list, tuple)):  # older jax: one dict per
                ca = ca[0] if ca else {}       # computation, take the entry
            try:
                mem = compiled.memory_analysis()
                mem_d = {a: getattr(mem, a) for a in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "alias_size_in_bytes",
                    "generated_code_size_in_bytes") if hasattr(mem, a)}
            except Exception:
                mem_d = {}
            hlo = compiled.as_text()
        stats = parse_collectives(hlo, chips)
        prog = analyze_program(hlo, chips)
        # XLA-CPU cost_analysis counts `while` bodies once (measured) — use
        # the loop-aware HLO analysis; keep raw values for reference.
        flops = float(prog["flops"])
        bytes_acc = float(prog["bytes"])
        flops_raw = float(ca.get("flops", 0.0))
        bytes_raw = float(ca.get("bytes accessed", 0.0))
        mf = M.model_flops(cfg, shape.kind, shape.seq_len,
                           shape.global_batch)
        compute_s = flops / mesh_lib.PEAK_FLOPS_BF16
        memory_s = bytes_acc / mesh_lib.HBM_BW
        coll_s = stats.raw_bytes / (chips * mesh_lib.ICI_BW)
        coll_link_s = stats.link_bytes / (2 * mesh_lib.ICI_BW)
        terms = {"compute_s": compute_s, "memory_s": memory_s,
                 "collective_s": coll_s, "collective_link_s": coll_link_s}
        dominant = max(("compute_s", "memory_s", "collective_link_s"),
                       key=lambda k: terms[k])
        rec.update(
            status="ok", lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            flops_per_chip=flops, bytes_per_chip=bytes_acc,
            flops_raw=flops_raw, bytes_raw=bytes_raw,
            model_flops=mf,
            useful_flops_ratio=(mf / (flops * chips) if flops else None),
            memory=mem_d, collectives=stats.summary(),
            top_collectives=stats.top(8),
            roofline=dict(terms, dominant=dominant),
            audit=list(sh.AUDIT.events),
        )
        if save_hlo:
            os.makedirs(out_dir, exist_ok=True)
            with gzip.open(os.path.join(
                    out_dir, f"{arch}__{shape_name}__{mesh_kind}.hlo.gz"),
                    "wt") as f:
                f.write(hlo)
    except Exception as e:  # noqa: BLE001 — record, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-4000:])
    rec["wall_s"] = round(time.time() - t0, 2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="enable beyond-paper optimization variants")
    args = ap.parse_args()

    archs = all_arch_names() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    os.makedirs(args.out, exist_ok=True)

    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                sfx = "__opt" if args.opt else ""
                path = os.path.join(args.out,
                                    f"{arch}__{shape}__{mk}{sfx}.json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip existing] {path}", flush=True)
                    continue
                rec = run_cell(arch, shape, mk, args.out, args.save_hlo,
                               opt=args.opt)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1, default=str)
                keys = ("status", "compile_s", "flops_per_chip",
                        "useful_flops_ratio")
                brief = {k: rec.get(k) for k in keys}
                if rec.get("status") == "ok":
                    brief["dominant"] = rec["roofline"]["dominant"]
                if rec.get("status") == "error":
                    brief["error"] = rec.get("error")
                print(f"[{arch} x {shape} x {mk}] {brief}", flush=True)
                if rec.get("status") == "ok":
                    mem = rec.get("memory") or {}
                    print("   memory_analysis:", mem, flush=True)
                    print("   cost: flops/chip=%.3e coll_raw=%.3e" % (
                        rec["flops_per_chip"],
                        rec["collectives"]["raw_bytes"]), flush=True)


if __name__ == "__main__":
    main()

"""Serving launcher: restore a checkpoint (or init) and serve batched
requests through the decode engine.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b \
      --ckpt-dir artifacts/ckpt_launch --batch 4 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import all_arch_names, get_config
from repro.models import model as M
from repro.models.params import init_tree
from repro.serve.engine import Engine, ServeConfig
from repro.train import checkpoint as ckpt
from repro.train.optimizer import init_opt_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b", choices=all_arch_names())
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--full-size", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.tiny()
    params = init_tree(M.model_specs(cfg), jax.random.key(0))
    if args.ckpt_dir:
        state_like = {"params": params, "opt": init_opt_state(params),
                      "step": jnp.zeros((), jnp.int32)}
        step, got = ckpt.restore_checkpoint(args.ckpt_dir, state_like)
        if got is not None:
            params = got["params"]
            print(f"restored checkpoint step {step}")
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=args.new_tokens,
                                          temperature=args.temperature))
    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab, jnp.int32)
    batch = {"tokens": prompts}
    if cfg.n_vision_tokens:
        batch["vision_embeds"] = jnp.zeros(
            (args.batch, cfg.n_vision_tokens, cfg.d_model), cfg.dtype)
    if cfg.is_encdec:
        batch["enc_embeds"] = jnp.zeros(
            (args.batch, cfg.enc_seq, cfg.d_model), cfg.dtype)
    t0 = time.time()
    out = eng.generate(batch)
    dt = time.time() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({out.size / dt:.1f} tok/s incl. compile)")
    print("sample:", out[0].tolist())


if __name__ == "__main__":
    main()

"""Parse collective traffic out of post-SPMD HLO text.

``cost_analysis()`` has no collective-bytes entry, so we parse the compiled
module for all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops, recover result shapes + replica-group sizes, and
attribute *loop multiplicity*: a collective inside a ``lax.scan``-derived
``while`` body executes trip_count times, so we
  1. split the module into computations,
  2. find every ``while`` op's (condition, body) pair,
  3. estimate trip counts from ``known_trip_count`` annotations or the
     largest s32 constant in the condition computation,
  4. propagate multipliers down nested loops.

Two numbers per op:
  raw_bytes   — result-operand sizes (the §Roofline prompt formula)
  link_bytes  — per-chip ring-egress estimate:
                  all-gather:      S * (g-1)/g      (S = full result)
                  reduce-scatter:  S_in * (g-1)/g   (S_in = result * g)
                  all-reduce:      2 * S * (g-1)/g
                  all-to-all:      S * (g-1)/g
                  collective-permute: S
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(.+?)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r"trip_count[\"':\s{]*[\"']?n?[\"']?[:=]\s*[\"']?(\d+)")
_S32_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, total_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return total_devices


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    name = None
    for line in text.splitlines():
        if not line.startswith(" ") and "{" in line and ("(" in line):
            tok = line.split()[0]
            if tok == "ENTRY":
                tok = line.split()[1]
            name = tok.lstrip("%")
            comps[name] = []
        elif line.startswith("}"):
            name = None
        elif name is not None:
            comps[name].append(line.strip())
    return comps


@dataclass
class CollectiveStats:
    ops: list = field(default_factory=list)

    @property
    def raw_bytes(self) -> float:
        return sum(o["raw_bytes"] for o in self.ops)

    @property
    def link_bytes(self) -> float:
        return sum(o["link_bytes"] for o in self.ops)

    def by_kind(self):
        agg = defaultdict(lambda: {"count": 0, "raw_bytes": 0.0,
                                   "link_bytes": 0.0})
        for o in self.ops:
            a = agg[o["kind"]]
            a["count"] += o["mult"]
            a["raw_bytes"] += o["raw_bytes"]
            a["link_bytes"] += o["link_bytes"]
        return dict(agg)

    def summary(self):
        return {"raw_bytes": self.raw_bytes, "link_bytes": self.link_bytes,
                "by_kind": self.by_kind(), "n_op_sites": len(self.ops)}

    def top(self, n=12):
        return sorted(self.ops, key=lambda o: -o["link_bytes"])[:n]


def _call_edges(comps):
    """(parent, callee, mult) edges: while bodies get their trip count,
    fusions/calls/reduces get 1. Returns (edges, fusion_bodies)."""
    edges: list[tuple[str, str, int]] = []
    fusion_bodies: set[str] = set()
    for parent, lines in comps.items():
        for line in lines:
            if " while(" in line or line.startswith("while("):
                m = _WHILE_RE.search(line)
                if m:
                    cond, body = m.group(1), m.group(2)
                    t = _TRIP_RE.search(line)
                    if t:
                        trip = int(t.group(1))
                    else:
                        consts = [int(c) for cl in comps.get(cond, [])
                                  for c in _S32_CONST_RE.findall(cl)]
                        trip = max(consts) if consts else 1
                    edges.append((parent, body, max(trip, 1)))
                    edges.append((parent, cond, max(trip, 1)))
                continue
            for attr in ("calls=", "to_apply="):
                for m in re.finditer(attr + r"%?([\w.\-]+)", line):
                    callee = m.group(1)
                    edges.append((parent, callee, 1))
                    if attr == "calls=" and " fusion(" in line:
                        fusion_bodies.add(callee)
    return edges, fusion_bodies


def _multipliers(edges) -> dict[str, float]:
    mult: dict[str, float] = defaultdict(lambda: 1.0)
    for _ in range(16):
        changed = False
        for parent, callee, trip in edges:
            new = mult[parent] * trip
            if abs(mult.get(callee, 1.0) - new) > 1e-9:
                mult[callee] = new
                changed = True
        if not changed:
            break
    return mult


_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[\w\[\],\s{}]+?\)?)\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "while", "conditional", "call",
}


def _dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def analyze_program(hlo_text: str, total_devices: int) -> dict:
    """Loop-aware FLOPs + HBM-traffic estimate from optimized HLO.

    XLA-CPU cost_analysis() counts `while` bodies once (measured); here
    every instruction is weighted by its loop-nest trip product. FLOPs
    counts dot ops (matmul-dominated workloads; elementwise is noise);
    bytes counts result+operand sizes of non-fusion-body instructions
    (fusion internals never touch HBM). Operand shapes are resolved via a
    per-computation symbol table (optimized HLO omits inline types).
    """
    comps = _split_computations(hlo_text)
    edges, fusion_bodies = _call_edges(comps)
    mult = _multipliers(edges)

    flops = 0.0
    bytes_ = 0.0
    dot_sites = 0
    for comp, lines in comps.items():
        m_ = mult.get(comp, 1.0)
        is_fusion_body = comp in fusion_bodies
        # symbol table: instruction name -> type string
        types: dict[str, str] = {}
        parsed = []
        for line in lines:
            im = _INSTR_RE.match(line)
            if not im:
                continue
            name, type_str, op = im.group(1), im.group(2), im.group(3)
            types[name] = type_str
            parsed.append((name, type_str, op, line))
        for name, type_str, op, line in parsed:
            # operand names: inside the op's parens, before attribute list
            paren = line.find(op + "(")
            rest = line[paren + len(op) + 1:]
            # cut at the matching close: attributes follow "), "
            depth, end = 1, len(rest)
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            opnames = _OPERAND_RE.findall(rest[:end])
            if op == "dot":
                lc = _LHS_CONTRACT_RE.search(line)
                if lc is not None and opnames:
                    ldims = _dims(types.get(opnames[0], ""))
                    rdims = _dims(type_str)
                    cdims = [int(i) for i in lc.group(1).split(",") if i]
                    csize = 1
                    for i in cdims:
                        if i < len(ldims):
                            csize *= ldims[i]
                    n = 1
                    for d in rdims:
                        n *= d
                    flops += 2.0 * n * csize * m_
                    dot_sites += 1
            if is_fusion_body or op in _SKIP_BYTES_OPS:
                continue
            b = _shape_bytes(type_str)
            for on in opnames:
                b += _shape_bytes(types.get(on, ""))
            bytes_ += b * m_
    return {"flops": flops, "bytes": bytes_, "dot_sites": dot_sites}


def parse_collectives(hlo_text: str, total_devices: int) -> CollectiveStats:
    comps = _split_computations(hlo_text)
    edges, _ = _call_edges(comps)
    mult = _multipliers(edges)

    stats = CollectiveStats()
    for comp, lines in comps.items():
        m_ = mult.get(comp, 1.0)
        for line in lines:
            cm = _COLL_RE.search(line)
            if not cm:
                continue
            type_str, kind = cm.group(1), cm.group(2)
            size = _shape_bytes(type_str)
            g = _group_size(line, total_devices)
            if g <= 1:
                continue
            if kind == "all-reduce":
                raw, link = size, 2 * size * (g - 1) / g
            elif kind == "all-gather":
                raw, link = size, size * (g - 1) / g
            elif kind == "reduce-scatter":
                raw, link = size * g, size * (g - 1)
            elif kind == "all-to-all":
                raw, link = size, size * (g - 1) / g
            else:
                raw, link = size, size
            stats.ops.append({
                "kind": kind, "bytes": size, "group": g, "mult": m_,
                "raw_bytes": raw * m_, "link_bytes": link * m_,
                "comp": comp, "line": line[:160],
            })
    return stats

"""Production mesh definitions.

A *function*, not a module-level constant — importing this module never
touches jax device state (smoke tests must keep seeing 1 CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (256-chip pod) or 2x16x16 (two pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(devices=None, *, multi_pod: bool = False):
    """Small mesh over however many devices exist (tests on forced hosts)."""
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    if multi_pod and n >= 8:
        return jax.make_mesh((2, 2, n // 4), ("pod", "data", "model"))
    if n >= 4:
        return jax.make_mesh((2, n // 2), ("data", "model"))
    return jax.make_mesh((1, n), ("data", "model"))


# TPU v5e-class hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link

"""Pallas TPU flash-attention kernel (forward).

Grid: (batch, heads, q_blocks, kv_blocks) — kv is the innermost (reduction)
axis; online-softmax statistics live in VMEM scratch across kv steps.
BlockSpecs tile q/k/v/o into (block, head_dim) VMEM tiles; with the default
bq=bk=256 and hd<=256 the working set is ~1.5MB of VMEM, and the MXU sees
(256, hd) x (hd, 256) matmuls (hardware-aligned for hd in {64,128,256}).

This is the TPU-target implementation of the same math as
``repro.models.layers.blockwise_sdpa`` (the jnp twin used on CPU and in the
dry-run); ``ref.py`` is the pure-jnp oracle both are tested against.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref,
                  l_ref, *, bq: int, bk: int, n_kv: int, causal: bool,
                  window: int | None, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)                # (bk, hd)
    s = q @ k.T                                        # (bq, bk)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_ref[...] +
                         jnp.log(jnp.maximum(l, 1e-30))).astype(jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "bq", "bk", "interpret",
                     "return_lse"))
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    bq: int = 256, bk: int = 256, interpret: bool = False,
                    return_lse: bool = False):
    """q,k,v: (B, H, S, hd) — H layout, GQA pre-repeated. Returns (B,H,S,hd)
    [, lse (B,H,S) f32 — consumed by the backward kernels]."""
    B, H, S, hd = q.shape
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    n_q, n_kv = S // bq, S // bk
    grid = (B, H, n_q, n_kv)
    kern = functools.partial(
        _flash_kernel, bq=bq, bk=bk, n_kv=n_kv, causal=causal,
        window=window, scale=hd ** -0.5)
    o, lse = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, qi, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, qi, ki: (b, h, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, qi, ki: (b, h, qi)),
        ],
        out_shape=[jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
                   jax.ShapeDtypeStruct((B, H, S), jnp.float32)],
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return (o, lse) if return_lse else o

"""Pallas TPU flash-attention backward kernels (FlashAttention-2 style).

Two passes, both recomputing probabilities from (q, k, lse) so nothing
O(S^2) is ever materialized in HBM:
  - dq kernel:  grid (B, H, nq, nk) — accumulates dq per q block over kv
  - dkv kernel: grid (B, H, nk, nq) — accumulates dk, dv per kv block over q

Inputs lse (B,H,S) and Drow = rowsum(do*o) (B,H,S) come from the forward
kernel / a cheap jnp reduction. `ops.mha_vjp` wires these into a
custom_vjp for end-to-end TPU training.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mask_blk(qi, ki, bq, bk, causal, window):
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= k_pos > q_pos - window
    return ok


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dr_ref, dq_ref,
               acc_ref, *, bq, bk, n_kv, causal, window, scale):
    qi, ki = pl.program_id(2), pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0].astype(jnp.float32)
    dr = dr_ref[0, 0].astype(jnp.float32)

    s = q @ k.T
    s = jnp.where(_mask_blk(qi, ki, bq, bk, causal, window), s, NEG_INF)
    p = jnp.exp(s - lse[:, None])
    dp = do @ v.T
    ds = p * (dp - dr[:, None]) * scale
    acc_ref[...] += ds @ k

    @pl.when(ki == n_kv - 1)
    def _done():
        dq_ref[0, 0] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dr_ref, dk_ref,
                dv_ref, dk_acc, dv_acc, *, bq, bk, n_q, causal, window,
                scale):
    ki, qi = pl.program_id(2), pl.program_id(3)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0].astype(jnp.float32)
    dr = dr_ref[0, 0].astype(jnp.float32)

    s = (q * scale) @ k.T
    s = jnp.where(_mask_blk(qi, ki, bq, bk, causal, window), s, NEG_INF)
    p = jnp.exp(s - lse[:, None])                    # (bq, bk)
    dv_acc[...] += p.T @ do
    dp = do @ v.T
    ds = p * (dp - dr[:, None]) * scale
    dk_acc[...] += ds.T @ q

    @pl.when(qi == n_q - 1)
    def _done():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq",
                                              "bk", "interpret"))
def flash_attention_bwd(q, k, v, do, lse, drow, *, causal=True, window=None,
                        bq=256, bk=256, interpret=False):
    """q,k,v,do: (B,H,S,hd); lse,drow: (B,H,S). Returns (dq, dk, dv)."""
    B, H, S, hd = q.shape
    bq, bk = min(bq, S), min(bk, S)
    n_q, n_kv = S // bq, S // bk
    scale = hd ** -0.5

    def spec4(b, which):
        if which == "q":
            return pl.BlockSpec((1, 1, b, hd),
                                lambda bi, h, i, j: (bi, h, i, 0))
        return pl.BlockSpec((1, 1, b, hd),
                            lambda bi, h, i, j: (bi, h, j, 0))

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, bq=bq, bk=bk, n_kv=n_kv,
                          causal=causal, window=window, scale=scale),
        grid=(B, H, n_q, n_kv),
        in_specs=[
            spec4(bq, "q"), spec4(bk, "kv"), spec4(bk, "kv"), spec4(bq, "q"),
            pl.BlockSpec((1, 1, bq), lambda bi, h, i, j: (bi, h, i)),
            pl.BlockSpec((1, 1, bq), lambda bi, h, i, j: (bi, h, i)),
        ],
        out_specs=spec4(bq, "q"),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, drow)

    def spec_kv(b, which):
        if which == "kv":
            return pl.BlockSpec((1, 1, b, hd),
                                lambda bi, h, i, j: (bi, h, i, 0))
        return pl.BlockSpec((1, 1, b, hd),
                            lambda bi, h, i, j: (bi, h, j, 0))

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, bq=bq, bk=bk, n_q=n_q,
                          causal=causal, window=window, scale=scale),
        grid=(B, H, n_kv, n_q),
        in_specs=[
            spec_kv(bq, "q"), spec_kv(bk, "kv"), spec_kv(bk, "kv"),
            spec_kv(bq, "q"),
            pl.BlockSpec((1, 1, bq), lambda bi, h, i, j: (bi, h, j)),
            pl.BlockSpec((1, 1, bq), lambda bi, h, i, j: (bi, h, j)),
        ],
        out_specs=[spec_kv(bk, "kv"), spec_kv(bk, "kv")],
        out_shape=[jax.ShapeDtypeStruct((B, H, S, hd), k.dtype),
                   jax.ShapeDtypeStruct((B, H, S, hd), v.dtype)],
        scratch_shapes=[pltpu.VMEM((bk, hd), jnp.float32),
                        pltpu.VMEM((bk, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, drow)
    return dq, dk, dv

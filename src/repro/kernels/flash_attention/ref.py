"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True,
                  window: int | None = None):
    """q,k,v: (B,H,S,hd). Materializes (S,S) scores — oracle only."""
    B, H, S, hd = q.shape
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (hd ** -0.5)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    s = jnp.where(ok, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)

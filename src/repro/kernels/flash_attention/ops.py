"""jit'd public wrappers for the flash attention kernels.

``mha`` is forward-only. ``mha_vjp`` is the full training op: forward and
backward both run as Pallas kernels (custom_vjp; nothing O(S^2) touches
HBM in either direction). On CPU containers both support interpret mode;
the model stack's default jnp twin lives in repro.models.layers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.kernel_bwd import flash_attention_bwd
from repro.kernels.flash_attention.ref import attention_ref


def mha(q, k, v, *, causal=True, window=None, bq=256, bk=256,
        force_interpret: bool | None = None):
    """q,k,v: (B,H,S,hd). Uses the Pallas kernel on TPU, interpret mode when
    requested, jnp reference otherwise."""
    platform = jax.devices()[0].platform
    if platform == "tpu":
        return flash_attention(q, k, v, causal=causal, window=window,
                               bq=bq, bk=bk)
    if force_interpret:
        return flash_attention(q, k, v, causal=causal, window=window,
                               bq=bq, bk=bk, interpret=True)
    return attention_ref(q, k, v, causal=causal, window=window)


@functools.lru_cache(maxsize=None)
def _mha_vjp_fn(causal, window, bq, bk, interpret):
    @jax.custom_vjp
    def f(q, k, v):
        return flash_attention(q, k, v, causal=causal, window=window,
                               bq=bq, bk=bk, interpret=interpret)

    def fwd(q, k, v):
        o, lse = flash_attention(q, k, v, causal=causal, window=window,
                                 bq=bq, bk=bk, interpret=interpret,
                                 return_lse=True)
        return o, (q, k, v, o, lse)

    def bwd(res, do):
        q, k, v, o, lse = res
        drow = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), -1)
        return flash_attention_bwd(q, k, v, do, lse, drow, causal=causal,
                                   window=window, bq=bq, bk=bk,
                                   interpret=interpret)

    f.defvjp(fwd, bwd)
    return f


def mha_vjp(q, k, v, *, causal=True, window=None, bq=256, bk=256,
            interpret=False):
    """Differentiable flash attention — Pallas fwd + bwd kernels."""
    return _mha_vjp_fn(bool(causal), window, int(bq), int(bk),
                       bool(interpret))(q, k, v)

"""VMEM budget planner for the event-loop kernel (pure python, no JAX).

The kernel keeps *every* per-replica buffer VMEM-resident for the whole
``n_events`` run, so its footprint per grid step is a closed-form function
of ``(tile, ev_chunk, T, N, K, P, lat_samples, representation)``. On real
TPU an oversized ``(tile, lat_samples)`` request dies inside Mosaic as an
opaque VMEM-exhaustion error; this planner computes the byte table up
front, **deterministically shrinks the replica tile** (halving) until the
configured budget fits, and raises an actionable ``ValueError`` when even
``tile=1`` cannot fit — never a silent wrong answer.

Byte formula (one grid step = one replica tile; int32/float32 = 4 bytes,
clocks = 8 bytes, as one i64 buffer or an (hi, lo) i32 pair):

  streamed inputs   u1/r2/r3: ``3 * tile*ev_chunk*4``, **x2** for the
                    pipeline double-buffer along the sequential event axis
  workload rows     edges/think ``tile*P*4`` each; locality/active
                    ``tile*P*T*4`` each; b_init ``tile*P*2*4``; cost_rows
                    ``tile*P*8*4``; node_mult ``tile*P*N*4``; thread_node
                    ``T*4``; lock_node ``K*4``
  outputs           done ``tile*T*4``; latency ring ``tile*lat_samples*8``;
                    lat_n/reacq/npass ``tile*4`` each; t_end ``tile*8``
  scratch           tails/victim ``3 * tile*K*4``; six per-thread i32
                    descriptors ``tile*T*4``; ready/op_start ``tile*T*8``;
                    busy ``tile*N*8``

The ``tile`` this planner receives is already padding-minimized by
``ops.plan_for_run`` (``ceil(B / ceil(B / tile))``: same grid-dim count,
smallest edge pad), so the byte table prices the tile the kernel really
runs, and the ragged event loop bounds itself at the true remaining
event count per chunk instead of masking dead steps.

``plan_vmem`` is exercised by ``tests/test_vmem_planner.py`` with no TPU:
the breakdown shapes are checked against the buffers ``ops.run_events``
actually allocates in interpret mode. The chosen plan is recorded via
``note_plan`` and surfaced through ``repro.core.batch.exec_stats()`` and
the ``benchmarks/perfcheck.py`` / ``benchmarks.run`` report rows.

>>> p = plan_vmem(tile=8, ev_chunk=512, T=16, N=4, K=16, P=1,
...               lat_samples=1 << 15, repr32=True)
>>> p.tile, p.shrunk, p.total_bytes == sum(
...     b for _, b in p.breakdown.values())
(8, False, True)
>>> tight = plan_vmem(tile=64, ev_chunk=512, T=16, N=4, K=16, P=1,
...                   lat_samples=1 << 15, repr32=True,
...                   budget=4 * 2**20)
>>> tight.requested_tile, tight.tile, tight.shrunk
(64, 8, True)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping

from repro.core.cost_model import N_COST_ROWS

#: Per-core VMEM on current TPUs is ~16 MiB; leave headroom for Mosaic's
#: own spills/temporaries. Overridable per call (``run_events(vmem_budget=)``).
DEFAULT_VMEM_BUDGET = 12 * 2**20

_I32 = 4
_F32 = 4
_CLOCK = 8          # one i64 buffer, or an (hi, lo) i32 pair — same bytes
#: the sequential event axis streams u1/r2/r3 chunk by chunk; Pallas
#: double-buffers streamed inputs so the next chunk loads during compute
PIPELINE_FACTOR = 2
#: the table entries the pipeline factor applies to — `repro.analysis`'s
#: vmem-consistency rule divides it back out when diffing the table
#: against the traced kernel's buffer bindings (in.u4 is the alock-rw
#: reader/writer coin stream and only present when ``rw=True``)
STREAMED_INPUTS = ("in.u1", "in.r2", "in.r3", "in.u4")


def _entries(name, shape, itemsize, factor=1):
    n = factor
    for d in shape:
        n *= d
    return (name, (shape, n * itemsize))


def _clock_entries(name, shape, repr32: bool):
    """One i64 buffer, or two i32 buffers for the hi/lo representation —
    the shapes here must match ``ops.run_events``'s allocations exactly."""
    if repr32:
        return [_entries(f"{name}.hi", shape, _I32),
                _entries(f"{name}.lo", shape, _I32)]
    return [_entries(name, shape, _CLOCK)]


def buffer_table(tile: int, ev_chunk: int, T: int, N: int, K: int, P: int,
                 lat_samples: int, repr32: bool, R: int = 0,
                 hl: bool = False, rw: bool = False) -> dict:
    """name -> (block shape, bytes) for every VMEM buffer of one grid step.

    Mirrors the ``in_specs`` / ``out_specs`` / ``scratch_shapes`` that
    ``ops.run_events`` builds — ``tests/test_vmem_planner.py`` asserts the
    two stay in sync. ``R > 0`` adds the open-loop request buffers (the
    arrival rows, the per-request wait/sojourn/status outputs and the
    dispatch scratch) in their exact binding positions; ``R == 0`` is the
    closed loop and reproduces the pre-traffic table unchanged. ``rw``
    (alock-rw) adds the streamed reader/writer coin, the per-phase read
    probabilities and the reader-count scratch; ``hl`` (hlock) adds the
    per-node rack row — both in their exact binding positions, and both
    inert for every other algorithm.
    """
    rows: list[tuple] = [
        # streamed draw inputs (STREAMED_INPUTS — double-buffered along
        # the event axis)
        _entries("in.u1", (tile, ev_chunk), _F32, PIPELINE_FACTOR),
        _entries("in.r2", (tile, ev_chunk), _I32, PIPELINE_FACTOR),
        _entries("in.r3", (tile, ev_chunk), _I32, PIPELINE_FACTOR),
        *([_entries("in.u4", (tile, ev_chunk), _F32, PIPELINE_FACTOR)]
          if rw else []),
        # per-phase workload rows (same block every chunk)
        _entries("in.edges", (tile, P), _I32),
        _entries("in.think", (tile, P), _I32),
        _entries("in.locality", (tile, P * T), _F32),
        *([_entries("in.read_frac", (tile, P * T), _F32)] if rw else []),
        _entries("in.active", (tile, P * T), _I32),
        _entries("in.b_init", (tile, P * 2), _I32),
        _entries("in.cost_rows", (tile, P * N_COST_ROWS), _I32),
        _entries("in.node_mult", (tile, P * N), _F32),
        _entries("in.thread_node", (1, T), _I32),
        _entries("in.lock_node", (1, K), _I32),
        *([_entries("in.rack", (tile, N), _I32)] if hl else []),
        # open-loop arrival rows (same block every chunk)
        *([*_clock_entries("in.arr", (tile, R), repr32),
           _entries("in.tok", (tile, R), _I32),
           _entries("in.tokcum", (tile, R), _I32),
           _entries("in.qcap", (tile, R), _I32)] if R else []),
        # outputs (flushed when the replica tile changes)
        _entries("out.done", (tile, T), _I32),
        *_clock_entries("out.lat", (tile, lat_samples), repr32),
        _entries("out.lat_n", (tile, 1), _I32),
        *_clock_entries("out.t_end", (tile, 1), repr32),
        _entries("out.reacq", (tile, 1), _I32),
        _entries("out.npass", (tile, 1), _I32),
        # open-loop per-request outputs
        *([*_clock_entries("out.wq", (tile, R), repr32),
           *_clock_entries("out.soj", (tile, R), repr32),
           _entries("out.rstat", (tile, R), _I32)] if R else []),
        # semantic scratch (int32 in every representation)
        _entries("scr.tail0", (tile, K), _I32),
        _entries("scr.tail1", (tile, K), _I32),
        _entries("scr.victim", (tile, K), _I32),
        _entries("scr.pc", (tile, T), _I32),
        _entries("scr.budget", (tile, T), _I32),
        _entries("scr.nxt", (tile, T), _I32),
        _entries("scr.prev", (tile, T), _I32),
        _entries("scr.target", (tile, T), _I32),
        _entries("scr.cohort", (tile, T), _I32),
        # alock-rw reader counts (between semantic and clock scratch)
        *([_entries("scr.word", (tile, K), _I32)] if rw else []),
        # clock scratch
        *_clock_entries("scr.ready", (tile, T), repr32),
        *_clock_entries("scr.busy", (tile, N), repr32),
        *_clock_entries("scr.op_start", (tile, T), repr32),
        # open-loop dispatch scratch
        *([_entries("scr.curreq", (tile, T), _I32),
           _entries("scr.arrptr", (tile, 1), _I32),
           _entries("scr.qlen", (tile, 1), _I32)] if R else []),
    ]
    return dict(rows)


@dataclass(frozen=True)
class VmemPlan:
    """The planner's verdict for one ``run_events`` call."""
    requested_tile: int
    tile: int
    ev_chunk: int
    lat_samples: int
    representation: str                      # "i64" | "i32pair"
    budget: int | None                       # bytes; None = unconstrained
    total_bytes: int
    breakdown: Mapping[str, tuple]           # name -> (shape, bytes)

    @property
    def shrunk(self) -> bool:
        return self.tile != self.requested_tile

    def as_dict(self) -> dict:
        """Compact form for ``exec_stats()`` / benchmark JSON rows."""
        return {
            "requested_tile": self.requested_tile, "tile": self.tile,
            "ev_chunk": self.ev_chunk, "lat_samples": self.lat_samples,
            "representation": self.representation, "budget": self.budget,
            "total_bytes": self.total_bytes, "shrunk": self.shrunk,
        }


def plan_vmem(*, tile: int, ev_chunk: int, T: int, N: int, K: int, P: int,
              lat_samples: int, repr32: bool, R: int = 0,
              hl: bool = False, rw: bool = False,
              budget: int | None = None) -> VmemPlan:
    """Compute the byte table; halve ``tile`` until ``budget`` fits.

    Deterministic: the same arguments always yield the same plan. With
    ``budget=None`` the table is computed but never shrunk (interpret
    mode / host runs have no VMEM ceiling). Raises ``ValueError`` when
    even ``tile=1`` exceeds the budget, naming the dominant buffers and
    the knobs that actually help.
    """
    if tile < 1 or ev_chunk < 1:
        raise ValueError(f"tile and ev_chunk must be >= 1, got "
                         f"(tile={tile}, ev_chunk={ev_chunk})")
    if budget is not None and budget < 1:
        raise ValueError(f"vmem budget must be >= 1 byte, got {budget}")
    requested = tile
    t = tile
    while True:
        table = buffer_table(t, ev_chunk, T, N, K, P, lat_samples, repr32,
                             R, hl, rw)
        total = sum(b for _, b in table.values())
        if budget is None or total <= budget or t == 1:
            break
        t = max(1, t // 2)
    if budget is not None and total > budget:
        top = sorted(table.items(), key=lambda kv: -kv[1][1])[:3]
        detail = ", ".join(f"{name}{shape}={b:,}B"
                           for name, (shape, b) in top)
        raise ValueError(
            f"event-loop kernel cannot fit VMEM budget {budget:,}B even at "
            f"tile=1 (needs {total:,}B; largest buffers: {detail}). Lower "
            f"lat_samples ({lat_samples}) or ev_chunk ({ev_chunk}), or "
            f"raise the budget (run_events(vmem_budget=...)).")
    return VmemPlan(requested_tile=requested, tile=t, ev_chunk=ev_chunk,
                    lat_samples=lat_samples,
                    representation="i32pair" if repr32 else "i64",
                    budget=budget, total_bytes=total, breakdown=table)


# -- last-plan registry (read by batch.exec_stats / benchmarks) -------------

_LAST_PLAN: VmemPlan | None = None


def note_plan(plan: VmemPlan) -> None:
    global _LAST_PLAN
    _LAST_PLAN = plan


def last_plan() -> VmemPlan | None:
    return _LAST_PLAN


def clear_plan() -> None:
    global _LAST_PLAN
    _LAST_PLAN = None

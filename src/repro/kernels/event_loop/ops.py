"""Public entry points for the Pallas event-loop backend.

``run_events`` mirrors ``sim._run_events``'s batched contract (leading
replica axis B on every per-replica operand) and returns the same tuple
(done, lat, lat_n, t_end, nreacq, npass). Replicas are padded to a tile
multiple and tiled across the first grid axis; events are padded to a chunk
multiple and streamed along the second (sequential) grid axis while the
simulation state persists in VMEM scratch.

The workload draw stream is precomputed here (``precompute_draws``) from
the identical ``jax.random.fold_in`` counter scheme the XLA loop uses —
draws depend only on (seed, event index), never on simulation state, so
hoisting them preserves bitwise equality while keeping the kernel integer-
only. The precompute itself is one vmapped pass fused into the surrounding
jit, not a per-event dispatch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.sim import I32, I64, LAT_SAMPLES
from repro.kernels.event_loop.kernel import event_loop_kernel

DEFAULT_TILE = 8
DEFAULT_EV_CHUNK = 4096


def default_interpret() -> bool:
    """Native Mosaic lowering on TPU; interpreter everywhere else."""
    return jax.default_backend() != "tpu"


def precompute_draws(seed, locality, zcdf, n_events: int, N: int, kpn: int):
    """The per-event workload draw stream, replica-batched.

    Returns int32 (B, n_events) arrays (go_local, remote_offset,
    zipf_offset) — exactly the values ``sim._run_events`` draws at event i
    from ``split(fold_in(key, i), 3)``, so consuming them in-kernel
    reproduces the XLA path bit for bit.
    """
    def one(sd, loc, cdf):
        key = jax.random.key(sd)

        def ev(i):
            k1, k2, k3 = jax.random.split(jax.random.fold_in(key, i), 3)
            go = jax.random.uniform(k1, dtype=jnp.float32) < loc
            r2 = jax.random.randint(k2, (), 0, max(N - 1, 1), dtype=I32)
            u3 = jax.random.uniform(k3, dtype=jnp.float32)
            r3 = jnp.minimum(jnp.sum(u3 >= cdf).astype(I32), kpn - 1)
            return go.astype(I32), r2, r3

        return jax.vmap(ev)(jnp.arange(n_events))

    return jax.vmap(one)(seed, locality, zcdf)


def run_events(alg, T, N, K, n_events, locality, b_init, thread_node,
               lock_node, costs, seed, zcdf, *, tile: int = DEFAULT_TILE,
               ev_chunk: int = DEFAULT_EV_CHUNK, interpret=None):
    """Batched Pallas event loop; must run under ``enable_x64()``.

    locality (B,) f32, b_init (B,2) i32, costs (B,8) i32 (or a tuple of 8
    (B,) arrays, as the XLA batch path passes them), seed (B,) i32,
    zcdf (B, K//N) f32; thread_node (T,)/lock_node (K,) broadcast. Returns
    (done (B,T) i32, lat (B,LAT_SAMPLES) i64, lat_n (B,) i32, t_end (B,)
    i64, nreacq (B,) i32, npass (B,) i32).

    B need not divide the replica tile and n_events need not divide the
    event chunk: replicas are edge-padded (duplicates, sliced off) and the
    final chunk masks events past n_events inside the kernel.
    """
    if interpret is None:
        interpret = default_interpret()
    if isinstance(costs, (tuple, list)):
        costs = jnp.stack(costs, axis=-1)
    B = locality.shape[0]
    if n_events < 1:
        # degenerate run: match the XLA loop's 0-iteration outputs instead
        # of tracing a zero-size grid (which Pallas rejects obscurely)
        return (jnp.zeros((B, T), I32),
                jnp.full((B, LAT_SAMPLES), -1, I64), jnp.zeros(B, I32),
                jnp.zeros(B, I64), jnp.zeros(B, I32), jnp.zeros(B, I32))
    kpn = K // N
    glocal, r2, r3 = precompute_draws(seed, locality, zcdf, n_events, N, kpn)

    tile = max(1, min(tile, B))
    pad_b = -B % tile
    ev_chunk = max(1, min(ev_chunk, n_events))
    pad_e = -n_events % ev_chunk

    def prep(a):
        a = jnp.asarray(a)
        return jnp.pad(a, ((0, pad_b),) + ((0, 0),) * (a.ndim - 1),
                       mode="edge") if pad_b else a

    glocal, r2, r3 = (jnp.pad(prep(a), ((0, 0), (0, pad_e))) if pad_e
                      else prep(a) for a in (glocal, r2, r3))
    b_init, costs = prep(b_init), prep(costs)
    Bp = B + pad_b
    n_chunks = (n_events + pad_e) // ev_chunk
    grid = (Bp // tile, n_chunks)

    def row(w):
        return pl.BlockSpec((tile, w), lambda i, j: (i, 0))

    out = pl.pallas_call(
        functools.partial(event_loop_kernel, alg=alg, T=T, N=N, K=K,
                          n_events=n_events, ev_chunk=ev_chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, ev_chunk), lambda i, j: (i, j)),
            pl.BlockSpec((tile, ev_chunk), lambda i, j: (i, j)),
            pl.BlockSpec((tile, ev_chunk), lambda i, j: (i, j)),
            row(2), row(8),
            pl.BlockSpec((1, T), lambda i, j: (0, 0)),
            pl.BlockSpec((1, K), lambda i, j: (0, 0)),
        ],
        out_specs=[row(T), row(LAT_SAMPLES), row(1), row(1), row(1), row(1)],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, T), I32),
            jax.ShapeDtypeStruct((Bp, LAT_SAMPLES), I64),
            jax.ShapeDtypeStruct((Bp, 1), I32),
            jax.ShapeDtypeStruct((Bp, 1), I64),
            jax.ShapeDtypeStruct((Bp, 1), I32),
            jax.ShapeDtypeStruct((Bp, 1), I32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tile, K), I32),   # tail0 / lock word
            pltpu.VMEM((tile, K), I32),   # tail1
            pltpu.VMEM((tile, K), I32),   # victim
            pltpu.VMEM((tile, T), I32),   # pc
            pltpu.VMEM((tile, T), I32),   # budget
            pltpu.VMEM((tile, T), I32),   # nxt
            pltpu.VMEM((tile, T), I32),   # prev
            pltpu.VMEM((tile, T), I32),   # target
            pltpu.VMEM((tile, T), I32),   # cohort
            pltpu.VMEM((tile, T), I64),   # ready
            pltpu.VMEM((tile, N), I64),   # busy
            pltpu.VMEM((tile, T), I64),   # op_start
        ],
        interpret=interpret,
    )(glocal, r2, r3, b_init,
      jnp.asarray(costs, I32),
      jnp.asarray(thread_node, I32)[None, :],
      jnp.asarray(lock_node, I32)[None, :])
    done, lat, lat_n, t_end, nreacq, npass = (o[:B] for o in out)
    return (done, lat, lat_n[:, 0], t_end[:, 0], nreacq[:, 0],
            npass[:, 0])


run_events_jit = functools.partial(
    jax.jit, static_argnames=("alg", "T", "N", "K", "n_events", "tile",
                              "ev_chunk", "interpret"))(run_events)

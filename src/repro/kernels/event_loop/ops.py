"""Public entry points for the Pallas event-loop backend.

``run_events`` mirrors ``sim._run_events``'s batched contract (a
``WorkloadOperands`` struct whose leaves carry a leading replica axis B)
and returns the same tuple (done, lat, lat_n, t_end, nreacq, npass).
Replicas are padded to a tile multiple and tiled across the first grid
axis; events are padded to a chunk multiple and streamed along the second
(sequential) grid axis while the simulation state persists in VMEM
scratch.

Clock representation
  ``representation="auto" | "i64" | "i32pair"`` picks how the kernel holds
  its 64-bit clock state (see ``kernel.py``): plain int64 (the interpret /
  XLA-adjacent fast path, callers hold ``enable_x64()``) or carry-correct
  hi/lo int32 pairs (``i32pair.py`` — what Mosaic can actually lower on
  TPU, and the only mode that works with x64 entirely off). ``"auto"``
  resolves to ``i32pair`` for native lowering and ``i64`` under interpret;
  the ``REPRO_EVENT_CLOCKS`` environment variable overrides either way.
  Both representations are bitwise-equal to the XLA engine
  (``tests/test_event_loop_native_repr.py``). ``run_events`` packs the
  pair outputs back into int64; ``run_events_pairs`` returns the raw
  (hi, lo) arrays and never touches an int64 — that is the entry point
  the x64-off CI leg exercises.

VMEM budget
  Before tracing, the ``vmem.py`` planner prices every VMEM buffer of one
  grid step and deterministically shrinks the replica tile to fit
  ``vmem_budget`` bytes (default: ``vmem.DEFAULT_VMEM_BUDGET`` for native
  lowering, unconstrained under interpret). An impossible budget raises an
  actionable ``ValueError`` instead of a Mosaic OOM; the chosen plan is
  recorded and surfaced through ``repro.core.batch.exec_stats()`` and the
  benchmark reports.

The state-independent half of the workload draw stream is precomputed here
(``precompute_draws``) from the identical ``jax.random.fold_in`` counter
scheme the XLA loop uses — the raw locality uniform, the remote-node
offset and the phase-resolved Zipf offset depend only on (seed, event
index), never on simulation state, so hoisting them preserves bitwise
equality. The *thread-dependent* half (comparing the uniform against
``locality[phase, tid]``) runs in-kernel, because ``tid`` is the argmin of
the ready clocks and only exists at runtime; the kernel receives the
per-phase per-thread locality / active-mask / think operands — and the
per-phase cost rows + ALock budgets — directly. The precompute itself is
one vmapped pass fused into the surrounding jit, not a per-event dispatch.

>>> import jax.numpy as jnp
>>> from repro.workloads import Workload, lower
>>> from repro.kernels.event_loop.ops import precompute_draws
>>> o = lower(Workload("alock", 2, 2, 8, locality=0.9), n_events=64).operands
>>> u1, r2, r3 = precompute_draws(jnp.asarray(o.seed)[None],
...                               jnp.asarray(o.edges)[None],
...                               jnp.asarray(o.zcdf)[None],
...                               n_events=64, N=2, kpn=4)
>>> u1.shape, str(r2.dtype), r3.shape
((1, 64), 'int32', (1, 64))

End-to-end, the kernel is selected with ``backend="pallas"`` (interpret
mode off-TPU) and must agree with the XLA loop bit for bit:

>>> from repro.core.sim import simulate
>>> w = Workload("alock", 2, 2, 8, locality=0.9, seed=1)
>>> rx = simulate(w, n_events=300, backend="xla")
>>> rp = simulate(w, n_events=300, backend="pallas")
>>> (rx.ops, rx.sim_ns) == (rp.ops, rp.sim_ns)
True
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.cost_model import N_COST_ROWS
from repro.core.sim import I32, I64, LAT_SAMPLES
from repro.kernels.event_loop import i32pair as p32
from repro.kernels.event_loop import vmem
from repro.kernels.event_loop.kernel import event_loop_kernel
from repro.traffic.stream import (arrival_plan, arrival_times_i64,
                                  arrival_times_pairs)

DEFAULT_TILE = 8
DEFAULT_EV_CHUNK = 4096

_REPRESENTATIONS = ("auto", "i64", "i32pair")


def default_interpret() -> bool:
    """Native Mosaic lowering on TPU; interpreter everywhere else."""
    return jax.default_backend() != "tpu"


def resolve_representation(representation: str, interpret: bool) -> str:
    """'auto' -> hi/lo i32 pairs for native TPU lowering (Mosaic has no
    64-bit vectors), plain i64 under interpret. ``REPRO_EVENT_CLOCKS``
    overrides the auto choice either way."""
    if representation not in _REPRESENTATIONS:
        raise ValueError(f"representation must be one of "
                         f"{_REPRESENTATIONS}, got {representation!r}")
    if representation == "auto":
        env = os.environ.get("REPRO_EVENT_CLOCKS", "")
        if env:
            if env not in ("i64", "i32pair"):
                raise ValueError(f"REPRO_EVENT_CLOCKS must be 'i64' or "
                                 f"'i32pair', got {env!r}")
            return env
        return "i64" if interpret else "i32pair"
    return representation


def precompute_draws(seed, edges, zcdf, n_events: int, N: int, kpn: int,
                     rw: bool = False):
    """The state-independent per-event draw stream, replica-batched.

    Returns (B, n_events) arrays (loc_uniform f32, remote_offset i32,
    zipf_offset i32) — exactly the values ``sim._run_events`` draws at
    event i from ``split(fold_in(key, i), 3)``. The Zipf inverse-CDF is
    resolved against the phase active at event i (phases are a pure
    function of the event index), so consuming the stream in-kernel
    reproduces the XLA path bit for bit. ``rw=True`` mirrors the
    alock-rw engine's 4-way split and appends the reader/writer coin
    uniform (f32) as a fourth stream.
    """
    def one(sd, ed, cdf):
        key = jax.random.key(sd)

        def ev(i):
            if rw:
                k1, k2, k3, k4 = jax.random.split(
                    jax.random.fold_in(key, i), 4)
            else:
                k1, k2, k3 = jax.random.split(jax.random.fold_in(key, i), 3)
            u1 = jax.random.uniform(k1, dtype=jnp.float32)
            r2 = jax.random.randint(k2, (), 0, max(N - 1, 1), dtype=I32)
            u3 = jax.random.uniform(k3, dtype=jnp.float32)
            ph = jnp.sum(i >= ed) - 1
            r3 = jnp.minimum(jnp.sum(u3 >= cdf[ph]).astype(I32), kpn - 1)
            if rw:
                u4 = jax.random.uniform(k4, dtype=jnp.float32)
                return u1, r2, r3, u4
            return u1, r2, r3

        return jax.vmap(ev)(jnp.arange(n_events))

    return jax.vmap(one)(seed, edges, zcdf)


def plan_for_run(B, P, n_events, T, N, K, *, R: int = 0,
                 tile: int = DEFAULT_TILE,
                 ev_chunk: int = DEFAULT_EV_CHUNK, interpret=None,
                 representation: str = "auto",
                 lat_samples: int = LAT_SAMPLES,
                 vmem_budget: int | None = None,
                 hl: bool = False, rw: bool = False) -> vmem.VmemPlan:
    """Resolve representation/budget, clamp (tile, ev_chunk) exactly like
    ``run_events`` will, and record the resulting VMEM plan.

    This is the *single* clamping+planning code path — ``run_events`` goes
    through it at trace time, and ``batch._exec_bucket`` calls it per
    dispatch so ``exec_stats()["vmem_plan"]`` stays populated even when
    the jitted kernel call is a cache hit (planning is python-level and
    does not re-run inside a cached executable).
    """
    if interpret is None:
        interpret = default_interpret()
    repr32 = resolve_representation(representation, interpret) == "i32pair"
    if vmem_budget is None and not interpret:
        vmem_budget = vmem.DEFAULT_VMEM_BUDGET
    tile = max(1, min(tile, B))
    # same grid-dim count, minimal edge padding: B=9, tile=8 pads 7 rows
    # of dead kernel work; tile=5 runs the same two tiles padding 1
    tile = -(-B // -(-B // tile))
    ev_chunk = max(1, min(ev_chunk, max(n_events, 1)))
    # price the VMEM footprint up front: shrink the replica tile to fit
    # the budget (or raise actionably) instead of dying inside Mosaic
    plan = vmem.plan_vmem(tile=tile, ev_chunk=ev_chunk, T=T, N=N, K=K, P=P,
                          lat_samples=lat_samples, repr32=repr32, R=R,
                          hl=hl, rw=rw, budget=vmem_budget)
    vmem.note_plan(plan)
    return plan


def _pallas_events(alg, T, N, K, n_events, wl, thread_node, lock_node, *,
                   tile, ev_chunk, interpret, repr32, lat_samples,
                   vmem_budget):
    """Shared pallas_call builder: returns the raw kernel outputs
    (clock outputs as (hi, lo) pairs when ``repr32``)."""
    B = wl.seed.shape[0]
    P = wl.edges.shape[1]
    R = wl.arr_fix.shape[-1]
    kpn = K // N
    is_hl = alg == "hlock"
    is_rw = alg == "alock-rw"
    streams = list(precompute_draws(wl.seed, wl.edges, wl.zcdf, n_events,
                                    N, kpn, rw=is_rw))

    plan = plan_for_run(B, P, n_events, T, N, K, R=R, tile=tile,
                        ev_chunk=ev_chunk, interpret=interpret,
                        representation="i32pair" if repr32 else "i64",
                        lat_samples=lat_samples, vmem_budget=vmem_budget,
                        hl=is_hl, rw=is_rw)
    tile, ev_chunk = plan.tile, plan.ev_chunk
    pad_b = -B % tile
    pad_e = -n_events % ev_chunk

    def prep(a):
        a = jnp.asarray(a)
        return jnp.pad(a, ((0, pad_b),) + ((0, 0),) * (a.ndim - 1),
                       mode="edge") if pad_b else a

    streams = [jnp.pad(prep(a), ((0, 0), (0, pad_e))) if pad_e
               else prep(a) for a in streams]
    # per-phase payloads ride flattened to 2D blocks (P*T / P*2 / P*8
    # lanes); the kernel reshapes them back — P is static via the shape
    locp = prep(wl.locality.reshape(B, P * T))
    actp = prep(wl.active.reshape(B, P * T))
    binit = prep(jnp.asarray(wl.b_init).reshape(B, P * 2))
    costp = prep(jnp.asarray(wl.cost_rows, I32).reshape(B, P * N_COST_ROWS))
    nmult = prep(jnp.asarray(wl.node_mult, jnp.float32).reshape(B, P * N))
    edges, think = (prep(a) for a in (wl.edges, wl.think_ns))
    if is_rw:
        readf = prep(jnp.asarray(wl.read_frac,
                                 jnp.float32).reshape(B, P * T))
    if is_hl:
        rackp = prep(jnp.asarray(wl.rack, I32))
    if R:
        # open loop: the arrival plan is state-independent, so it is
        # precomputed here with the *same* shared repro.traffic.stream
        # helpers the XLA loop traces — the arrival times ride in as a
        # clock-typed input and come back out verbatim as output #7
        aplan = jax.vmap(lambda w: arrival_plan(w, n_events))(wl)
        if repr32:
            arr = jax.vmap(arrival_times_pairs)(aplan.gaps)
            arr_in = [prep(arr[0]), prep(arr[1])]
        else:
            arr = jax.vmap(arrival_times_i64)(aplan.gaps)
            arr_in = [prep(arr)]
        tokp = prep(jnp.asarray(aplan.tok, I32))
        tokcp = prep(jnp.asarray(aplan.tokcum, I32))
        qcapp = prep(jnp.asarray(aplan.qcap, I32))
    Bp = B + pad_b
    n_chunks = (n_events + pad_e) // ev_chunk
    grid = (Bp // tile, n_chunks)

    def row(w):
        return pl.BlockSpec((tile, w), lambda i, j: (i, 0))

    def clock_out(w):
        """One (Bp, w) i64 output, or an (hi, lo) pair of i32 outputs."""
        if repr32:
            return ([row(w), row(w)],
                    [jax.ShapeDtypeStruct((Bp, w), I32)] * 2)
        return [row(w)], [jax.ShapeDtypeStruct((Bp, w), I64)]

    def clock_scratch(w):
        if repr32:
            return [pltpu.VMEM((tile, w), I32)] * 2
        return [pltpu.VMEM((tile, w), I64)]

    lat_specs, lat_shapes = clock_out(lat_samples)
    tend_specs, tend_shapes = clock_out(1)
    out_specs = ([row(T)] + lat_specs + [row(1)] + tend_specs
                 + [row(1), row(1)])
    out_shape = ([jax.ShapeDtypeStruct((Bp, T), I32)] + lat_shapes
                 + [jax.ShapeDtypeStruct((Bp, 1), I32)] + tend_shapes
                 + [jax.ShapeDtypeStruct((Bp, 1), I32),
                    jax.ShapeDtypeStruct((Bp, 1), I32)])
    if R:
        wq_specs, wq_shapes = clock_out(R)
        soj_specs, soj_shapes = clock_out(R)
        out_specs += wq_specs + soj_specs + [row(R)]
        out_shape += (wq_shapes + soj_shapes
                      + [jax.ShapeDtypeStruct((Bp, R), I32)])
    scratch_shapes = [
        pltpu.VMEM((tile, K), I32),   # tail0 / lock word
        pltpu.VMEM((tile, K), I32),   # tail1
        pltpu.VMEM((tile, K), I32),   # victim
        pltpu.VMEM((tile, T), I32),   # pc
        pltpu.VMEM((tile, T), I32),   # budget
        pltpu.VMEM((tile, T), I32),   # nxt
        pltpu.VMEM((tile, T), I32),   # prev
        pltpu.VMEM((tile, T), I32),   # target
        pltpu.VMEM((tile, T), I32),   # cohort
        # alock-rw reader counts ride between the semantic and clock
        # scratch (matching the kernel's unpack and vmem.buffer_table)
        *([pltpu.VMEM((tile, K), I32)] if is_rw else []),
        *clock_scratch(T),            # ready
        *clock_scratch(N),            # busy
        *clock_scratch(T),            # op_start
    ]
    in_specs = (
        [pl.BlockSpec((tile, ev_chunk), lambda i, j: (i, j))] * len(streams)
        + [row(P), row(P), row(P * T)]
        + ([row(P * T)] if is_rw else [])          # read_frac rows
        + [row(P * T), row(P * 2), row(P * N_COST_ROWS), row(P * N),
           pl.BlockSpec((1, T), lambda i, j: (0, 0)),
           pl.BlockSpec((1, K), lambda i, j: (0, 0))]
        + ([row(N)] if is_hl else []))             # rack row
    operands = [*streams,
                jnp.asarray(edges, I32), jnp.asarray(think, I32),
                jnp.asarray(locp, jnp.float32)]
    if is_rw:
        operands += [jnp.asarray(readf, jnp.float32)]
    operands += [jnp.asarray(actp, I32),
                 jnp.asarray(binit, I32), jnp.asarray(costp, I32),
                 jnp.asarray(nmult, jnp.float32),
                 jnp.asarray(thread_node, I32)[None, :],
                 jnp.asarray(lock_node, I32)[None, :]]
    if is_hl:
        operands += [jnp.asarray(rackp, I32)]
    if R:
        in_specs += [row(R)] * (len(arr_in) + 3)
        operands += [*arr_in, tokp, tokcp, qcapp]
        scratch_shapes += [pltpu.VMEM((tile, T), I32),   # curreq
                           pltpu.VMEM((tile, 1), I32),   # arrptr
                           pltpu.VMEM((tile, 1), I32)]   # qlen

    out = pl.pallas_call(
        functools.partial(event_loop_kernel, alg=alg, T=T, N=N, K=K, P=P,
                          n_events=n_events, ev_chunk=ev_chunk,
                          lat_samples=lat_samples, repr32=repr32, R=R),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(*operands)

    out = [o[:B] for o in out]
    if repr32:
        (done, lat_hi, lat_lo, lat_n, te_hi, te_lo, nreacq, npass,
         *extra) = out
        base = (done, (lat_hi, lat_lo), lat_n[:, 0],
                (te_hi[:, 0], te_lo[:, 0]), nreacq[:, 0], npass[:, 0])
        if R:
            wq_hi, wq_lo, soj_hi, soj_lo, rstat = extra
            return base + (arr, (wq_hi, wq_lo), (soj_hi, soj_lo), rstat)
        return base
    done, lat, lat_n, t_end, nreacq, npass, *extra = out
    base = (done, lat, lat_n[:, 0], t_end[:, 0], nreacq[:, 0],
            npass[:, 0])
    if R:
        wq, soj, rstat = extra
        return base + (arr, wq, soj, rstat)
    return base


def run_events(alg, T, N, K, n_events, wl, thread_node, lock_node, *,
               tile: int = DEFAULT_TILE, ev_chunk: int = DEFAULT_EV_CHUNK,
               interpret=None, representation: str = "auto",
               lat_samples: int = LAT_SAMPLES,
               vmem_budget: int | None = None):
    """Batched Pallas event loop; must run under ``enable_x64()`` (the
    int64 output contract — use :func:`run_events_pairs` to stay in pure
    int32 with x64 off).

    ``wl`` is a ``WorkloadOperands`` with a leading replica axis B on
    every leaf: locality (B,P,T) f32, zcdf (B,P,K//N) f32, edges (B,P)
    i32, think_ns (B,P) i32, active (B,P,T) i32, b_init (B,P,2) i32,
    cost_rows (B,P,8) i32, node_mult (B,P,N) f32, seed (B,) i32;
    thread_node (T,)/lock_node (K,) broadcast. Returns (done (B,T) i32,
    lat (B,lat_samples) i64, lat_n (B,) i32, t_end (B,) i64,
    nreacq (B,) i32, npass (B,) i32).

    Open-loop workloads (``wl.arr_fix`` non-empty, R request slots) return
    four extra arrays mirroring ``sim._run_events``: arr (B,R) i64 arrival
    times, wq (B,R) i64 queue waits, soj (B,R) i64 sojourns (-1 when never
    dispatched/completed) and rstat (B,R) i32 ``repro.traffic`` status
    codes.

    B need not divide the replica tile and n_events need not divide the
    event chunk: replicas are edge-padded (duplicates, sliced off) and the
    final chunk masks events past n_events inside the kernel. The tile may
    additionally be shrunk by the VMEM planner (see module docstring);
    ``vmem_budget=None`` means the default budget for native lowering and
    unconstrained under interpret.
    """
    if interpret is None:
        interpret = default_interpret()
    repr32 = resolve_representation(representation, interpret) == "i32pair"
    B = wl.seed.shape[0]
    R = wl.arr_fix.shape[-1]
    if n_events < 1:
        # degenerate run: match the XLA loop's 0-iteration outputs instead
        # of tracing a zero-size grid (which Pallas rejects obscurely)
        base = (jnp.zeros((B, T), I32),
                jnp.full((B, lat_samples), -1, I64), jnp.zeros(B, I32),
                jnp.zeros(B, I64), jnp.zeros(B, I32), jnp.zeros(B, I32))
        if R:
            aplan = jax.vmap(lambda w: arrival_plan(w, n_events))(wl)
            arr = jax.vmap(arrival_times_i64)(aplan.gaps)
            return base + (arr, jnp.full((B, R), -1, I64),
                           jnp.full((B, R), -1, I64),
                           jnp.zeros((B, R), I32))
        return base
    out = _pallas_events(alg, T, N, K, n_events, wl, thread_node,
                         lock_node, tile=tile, ev_chunk=ev_chunk,
                         interpret=interpret, repr32=repr32,
                         lat_samples=lat_samples, vmem_budget=vmem_budget)
    if repr32:
        done, lat, lat_n, t_end, nreacq, npass = out[:6]
        base = (done, p32.pack(lat), lat_n, p32.pack(t_end), nreacq, npass)
        if R:
            arr, wq, soj, rstat = out[6:]
            return base + (p32.pack(arr), p32.pack(wq), p32.pack(soj),
                           rstat)
        return base
    return out


def run_events_pairs(alg, T, N, K, n_events, wl, thread_node, lock_node, *,
                     tile: int = DEFAULT_TILE,
                     ev_chunk: int = DEFAULT_EV_CHUNK, interpret=None,
                     lat_samples: int = LAT_SAMPLES,
                     vmem_budget: int | None = None):
    """The hi/lo representation end to end — no int64 anywhere, so it runs
    with x64 off (the TPU vector constraint the x64-off CI leg emulates).

    Returns (done (B,T) i32, (lat_hi, lat_lo) (B,lat_samples) i32 each,
    lat_n (B,) i32, (t_end_hi, t_end_lo) (B,) i32 each, nreacq (B,) i32,
    npass (B,) i32); combine pairs host-side with ``i32pair.pack_np``.
    Open-loop workloads append (arr, wq, soj) as (hi, lo) pairs of
    (B,R) i32 each plus rstat (B,R) i32.
    """
    if interpret is None:
        interpret = default_interpret()
    B = wl.seed.shape[0]
    R = wl.arr_fix.shape[-1]
    if n_events < 1:
        z1 = jnp.zeros(B, I32)
        base = (jnp.zeros((B, T), I32),
                (jnp.full((B, lat_samples), -1, I32),
                 jnp.full((B, lat_samples), -1, I32)),
                z1, (z1, z1), z1, z1)
        if R:
            aplan = jax.vmap(lambda w: arrival_plan(w, n_events))(wl)
            arr = jax.vmap(arrival_times_pairs)(aplan.gaps)
            m1 = p32.pfull((B, R), -1)
            return base + (arr, m1, m1, jnp.zeros((B, R), I32))
        return base
    return _pallas_events(alg, T, N, K, n_events, wl, thread_node,
                          lock_node, tile=tile, ev_chunk=ev_chunk,
                          interpret=interpret, repr32=True,
                          lat_samples=lat_samples, vmem_budget=vmem_budget)


_jit_run_events = functools.partial(
    jax.jit, static_argnames=("alg", "T", "N", "K", "n_events", "tile",
                              "ev_chunk", "interpret", "representation",
                              "lat_samples", "vmem_budget"))(run_events)


def run_events_jit(alg, T, N, K, n_events, wl, thread_node, lock_node, *,
                   tile: int = DEFAULT_TILE,
                   ev_chunk: int = DEFAULT_EV_CHUNK, interpret=None,
                   representation: str = "auto",
                   lat_samples: int = LAT_SAMPLES,
                   vmem_budget: int | None = None):
    """Jitted ``run_events`` with the environment-dependent knobs resolved
    *eagerly*, so they participate in the jit cache key — a cached
    executable traced under ``"auto"`` would otherwise silently ignore a
    later ``REPRO_EVENT_CLOCKS`` change (the env read inside a jitted
    function only happens at trace time)."""
    if interpret is None:
        interpret = default_interpret()
    representation = resolve_representation(representation, interpret)
    return _jit_run_events(alg, T, N, K, n_events, wl, thread_node,
                           lock_node, tile=tile, ev_chunk=ev_chunk,
                           interpret=interpret,
                           representation=representation,
                           lat_samples=lat_samples,
                           vmem_budget=vmem_budget)

"""Public entry points for the Pallas event-loop backend.

``run_events`` mirrors ``sim._run_events``'s batched contract (a
``WorkloadOperands`` struct whose leaves carry a leading replica axis B)
and returns the same tuple (done, lat, lat_n, t_end, nreacq, npass).
Replicas are padded to a tile multiple and tiled across the first grid
axis; events are padded to a chunk multiple and streamed along the second
(sequential) grid axis while the simulation state persists in VMEM
scratch.

The state-independent half of the workload draw stream is precomputed here
(``precompute_draws``) from the identical ``jax.random.fold_in`` counter
scheme the XLA loop uses — the raw locality uniform, the remote-node
offset and the phase-resolved Zipf offset depend only on (seed, event
index), never on simulation state, so hoisting them preserves bitwise
equality. The *thread-dependent* half (comparing the uniform against
``locality[phase, tid]``) runs in-kernel, because ``tid`` is the argmin of
the ready clocks and only exists at runtime; the kernel receives the
per-phase per-thread locality / active-mask / think operands — and the
per-phase cost rows + ALock budgets — directly. The precompute itself is
one vmapped pass fused into the surrounding jit, not a per-event dispatch.

>>> import jax.numpy as jnp
>>> from repro.workloads import Workload, lower
>>> from repro.kernels.event_loop.ops import precompute_draws
>>> o = lower(Workload("alock", 2, 2, 8, locality=0.9), n_events=64).operands
>>> u1, r2, r3 = precompute_draws(jnp.asarray(o.seed)[None],
...                               jnp.asarray(o.edges)[None],
...                               jnp.asarray(o.zcdf)[None],
...                               n_events=64, N=2, kpn=4)
>>> u1.shape, str(r2.dtype), r3.shape
((1, 64), 'int32', (1, 64))

End-to-end, the kernel is selected with ``backend="pallas"`` (interpret
mode off-TPU) and must agree with the XLA loop bit for bit:

>>> from repro.core.sim import simulate
>>> w = Workload("alock", 2, 2, 8, locality=0.9, seed=1)
>>> rx = simulate(w, n_events=300, backend="xla")
>>> rp = simulate(w, n_events=300, backend="pallas")
>>> (rx.ops, rx.sim_ns) == (rp.ops, rp.sim_ns)
True
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.cost_model import N_COST_ROWS
from repro.core.sim import I32, I64, LAT_SAMPLES
from repro.kernels.event_loop.kernel import event_loop_kernel

DEFAULT_TILE = 8
DEFAULT_EV_CHUNK = 4096


def default_interpret() -> bool:
    """Native Mosaic lowering on TPU; interpreter everywhere else."""
    return jax.default_backend() != "tpu"


def precompute_draws(seed, edges, zcdf, n_events: int, N: int, kpn: int):
    """The state-independent per-event draw stream, replica-batched.

    Returns (B, n_events) arrays (loc_uniform f32, remote_offset i32,
    zipf_offset i32) — exactly the values ``sim._run_events`` draws at
    event i from ``split(fold_in(key, i), 3)``. The Zipf inverse-CDF is
    resolved against the phase active at event i (phases are a pure
    function of the event index), so consuming the stream in-kernel
    reproduces the XLA path bit for bit.
    """
    def one(sd, ed, cdf):
        key = jax.random.key(sd)

        def ev(i):
            k1, k2, k3 = jax.random.split(jax.random.fold_in(key, i), 3)
            u1 = jax.random.uniform(k1, dtype=jnp.float32)
            r2 = jax.random.randint(k2, (), 0, max(N - 1, 1), dtype=I32)
            u3 = jax.random.uniform(k3, dtype=jnp.float32)
            ph = jnp.sum(i >= ed) - 1
            r3 = jnp.minimum(jnp.sum(u3 >= cdf[ph]).astype(I32), kpn - 1)
            return u1, r2, r3

        return jax.vmap(ev)(jnp.arange(n_events))

    return jax.vmap(one)(seed, edges, zcdf)


def run_events(alg, T, N, K, n_events, wl, thread_node, lock_node, *,
               tile: int = DEFAULT_TILE, ev_chunk: int = DEFAULT_EV_CHUNK,
               interpret=None):
    """Batched Pallas event loop; must run under ``enable_x64()``.

    ``wl`` is a ``WorkloadOperands`` with a leading replica axis B on
    every leaf: locality (B,P,T) f32, zcdf (B,P,K//N) f32, edges (B,P)
    i32, think_ns (B,P) i32, active (B,P,T) i32, b_init (B,P,2) i32,
    cost_rows (B,P,8) i32, seed (B,) i32; thread_node (T,)/lock_node (K,)
    broadcast. Returns (done (B,T) i32, lat (B,LAT_SAMPLES) i64, lat_n
    (B,) i32, t_end (B,) i64, nreacq (B,) i32, npass (B,) i32).

    B need not divide the replica tile and n_events need not divide the
    event chunk: replicas are edge-padded (duplicates, sliced off) and the
    final chunk masks events past n_events inside the kernel.
    """
    if interpret is None:
        interpret = default_interpret()
    B = wl.seed.shape[0]
    P = wl.edges.shape[1]
    if n_events < 1:
        # degenerate run: match the XLA loop's 0-iteration outputs instead
        # of tracing a zero-size grid (which Pallas rejects obscurely)
        return (jnp.zeros((B, T), I32),
                jnp.full((B, LAT_SAMPLES), -1, I64), jnp.zeros(B, I32),
                jnp.zeros(B, I64), jnp.zeros(B, I32), jnp.zeros(B, I32))
    kpn = K // N
    u1, r2, r3 = precompute_draws(wl.seed, wl.edges, wl.zcdf, n_events, N,
                                  kpn)

    tile = max(1, min(tile, B))
    pad_b = -B % tile
    ev_chunk = max(1, min(ev_chunk, n_events))
    pad_e = -n_events % ev_chunk

    def prep(a):
        a = jnp.asarray(a)
        return jnp.pad(a, ((0, pad_b),) + ((0, 0),) * (a.ndim - 1),
                       mode="edge") if pad_b else a

    u1, r2, r3 = (jnp.pad(prep(a), ((0, 0), (0, pad_e))) if pad_e
                  else prep(a) for a in (u1, r2, r3))
    # per-phase payloads ride flattened to 2D blocks (P*T / P*2 / P*8
    # lanes); the kernel reshapes them back — P is static via the shape
    locp = prep(wl.locality.reshape(B, P * T))
    actp = prep(wl.active.reshape(B, P * T))
    binit = prep(jnp.asarray(wl.b_init).reshape(B, P * 2))
    costp = prep(jnp.asarray(wl.cost_rows, I32).reshape(B, P * N_COST_ROWS))
    edges, think = (prep(a) for a in (wl.edges, wl.think_ns))
    Bp = B + pad_b
    n_chunks = (n_events + pad_e) // ev_chunk
    grid = (Bp // tile, n_chunks)

    def row(w):
        return pl.BlockSpec((tile, w), lambda i, j: (i, 0))

    out = pl.pallas_call(
        functools.partial(event_loop_kernel, alg=alg, T=T, N=N, K=K, P=P,
                          n_events=n_events, ev_chunk=ev_chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, ev_chunk), lambda i, j: (i, j)),
            pl.BlockSpec((tile, ev_chunk), lambda i, j: (i, j)),
            pl.BlockSpec((tile, ev_chunk), lambda i, j: (i, j)),
            row(P), row(P), row(P * T), row(P * T),
            row(P * 2), row(P * N_COST_ROWS),
            pl.BlockSpec((1, T), lambda i, j: (0, 0)),
            pl.BlockSpec((1, K), lambda i, j: (0, 0)),
        ],
        out_specs=[row(T), row(LAT_SAMPLES), row(1), row(1), row(1), row(1)],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, T), I32),
            jax.ShapeDtypeStruct((Bp, LAT_SAMPLES), I64),
            jax.ShapeDtypeStruct((Bp, 1), I32),
            jax.ShapeDtypeStruct((Bp, 1), I64),
            jax.ShapeDtypeStruct((Bp, 1), I32),
            jax.ShapeDtypeStruct((Bp, 1), I32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tile, K), I32),   # tail0 / lock word
            pltpu.VMEM((tile, K), I32),   # tail1
            pltpu.VMEM((tile, K), I32),   # victim
            pltpu.VMEM((tile, T), I32),   # pc
            pltpu.VMEM((tile, T), I32),   # budget
            pltpu.VMEM((tile, T), I32),   # nxt
            pltpu.VMEM((tile, T), I32),   # prev
            pltpu.VMEM((tile, T), I32),   # target
            pltpu.VMEM((tile, T), I32),   # cohort
            pltpu.VMEM((tile, T), I64),   # ready
            pltpu.VMEM((tile, N), I64),   # busy
            pltpu.VMEM((tile, T), I64),   # op_start
        ],
        interpret=interpret,
    )(u1, r2, r3,
      jnp.asarray(edges, I32), jnp.asarray(think, I32),
      jnp.asarray(locp, jnp.float32), jnp.asarray(actp, I32),
      jnp.asarray(binit, I32), jnp.asarray(costp, I32),
      jnp.asarray(thread_node, I32)[None, :],
      jnp.asarray(lock_node, I32)[None, :])
    done, lat, lat_n, t_end, nreacq, npass = (o[:B] for o in out)
    return (done, lat, lat_n[:, 0], t_end[:, 0], nreacq[:, 0],
            npass[:, 0])


run_events_jit = functools.partial(
    jax.jit, static_argnames=("alg", "T", "N", "K", "n_events", "tile",
                              "ev_chunk", "interpret"))(run_events)

"""Carry-correct hi/lo int32 arithmetic for the kernel's 64-bit clocks.

Mosaic — the Pallas TPU compiler — has no 64-bit vector registers, so the
event-loop kernel's int64 clock state (``ready``/``busy``/``op_start``,
latency stamps, the parked-thread ``never`` sentinel) fails native
lowering. This module is the replacement representation: every 64-bit
quantity is a **pair** ``(hi, lo)`` of equal-shaped int32 arrays encoding

    value = hi * 2**32 + u32(lo)

where ``lo`` is the *unsigned* low word reinterpreted as int32. Ordering
is lexicographic on ``(hi signed, lo unsigned)``, which coincides with
int64 ordering for every value (the sign lives in ``hi``), so compares,
min/max and argmin reproduce the int64 engine **bit for bit** — the
differential suite (``tests/test_event_loop_native_repr.py``) asserts it
end-to-end and ``tests/test_i32pair.py`` property-tests every helper
across carry boundaries.

All helpers are pure ``jnp`` over int32: they trace identically with and
without x64 enabled and inside Pallas kernels (interpret or native).
``pack``/``unpack`` convert to/from real int64 arrays (x64 required);
``pack_np``/``unpack_np`` are the numpy equivalents for tests and hosts
where x64 stays off.

>>> import numpy as np
>>> hi, lo = unpack_np(np.int64([2**32 + 5, -1, 2**31]))
>>> (hi.tolist(), lo.tolist())
([1, -1, 0], [5, -1, -2147483648])
>>> pack_np(hi, lo).tolist()
[4294967301, -1, 2147483648]
>>> carry = padd_i32(unpack_np(np.int64([2**32 - 1])), np.int32(1))
>>> pack_np(*carry).tolist()               # lo wraps, carry into hi
[4294967296]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

I32 = jnp.int32
_INT32_MIN = np.int32(-2**31)
_INT32_MAX = np.int32(2**31 - 1)
_U32_MASK = 0xFFFFFFFF

#: int64 max as a pair — the "parked thread" sentinel that loses every
#: argmin. hi carries INT32_MAX, lo carries the all-ones low word (-1).
NEVER = (_INT32_MAX, np.int32(-1))


def _u(lo):
    """Bias the low word so *signed* comparison orders it *unsigned*."""
    return lo ^ _INT32_MIN


# -- construction -----------------------------------------------------------


def pfull(shape, value: int):
    """Pair filled with a python-int constant (any int64 value).

    >>> import numpy as np
    >>> h, l = pfull((2,), -1)
    >>> (np.asarray(h).tolist(), np.asarray(l).tolist())
    ([-1, -1], [-1, -1])
    """
    hi = value >> 32
    lo = value & _U32_MASK
    if lo >= 1 << 31:
        lo -= 1 << 32
    return (jnp.full(shape, np.int32(hi), I32),
            jnp.full(shape, np.int32(lo), I32))


def pzeros(shape):
    return pfull(shape, 0)


def from_i32(x):
    """Sign-extend an int32 array into a pair (exact for any int32)."""
    x = jnp.asarray(x, I32)
    return (jnp.where(x < 0, np.int32(-1), np.int32(0)).astype(I32), x)


# -- arithmetic -------------------------------------------------------------


def padd(a, b):
    """Pair + pair with carry (wraps mod 2**64, like int64)."""
    lo = a[1] + b[1]
    carry = (_u(lo) < _u(a[1])).astype(I32)
    return (a[0] + b[0] + carry, lo)


def padd_i32(a, d):
    """Pair + int32 delta (either sign), carry-correct."""
    return padd(a, from_i32(d))


def psub(a, b):
    """Pair - pair with borrow (wraps mod 2**64, like int64)."""
    lo = a[1] - b[1]
    borrow = (_u(a[1]) < _u(b[1])).astype(I32)
    return (a[0] - b[0] - borrow, lo)


# -- comparison / selection -------------------------------------------------


def plt(a, b):
    return (a[0] < b[0]) | ((a[0] == b[0]) & (_u(a[1]) < _u(b[1])))


def ple(a, b):
    return (a[0] < b[0]) | ((a[0] == b[0]) & (_u(a[1]) <= _u(b[1])))


def peq(a, b):
    return (a[0] == b[0]) & (a[1] == b[1])


def pwhere(c, a, b):
    """Elementwise select between pairs (``c`` broadcasts per component)."""
    return (jnp.where(c, a[0], b[0]), jnp.where(c, a[1], b[1]))


def pmin2(a, b):
    return pwhere(plt(a, b), a, b)


def pmax2(a, b):
    return pwhere(plt(a, b), b, a)


# -- gathers / reductions (axis-1 over 2D, the kernel's layout) -------------


def pgather(oh, p, axis=1):
    """One-hot gather: ``oh`` has exactly one True per reduced row. Sum
    dtypes are pinned to int32 so enabling x64 cannot widen them."""
    return (jnp.sum(jnp.where(oh, p[0], np.int32(0)), axis=axis, dtype=I32),
            jnp.sum(jnp.where(oh, p[1], np.int32(0)), axis=axis, dtype=I32))


def reduce_min_masked(p, mask, axis=1):
    """min over ``axis`` with masked-out entries read as ``NEVER`` —
    the pair form of ``jnp.min(jnp.where(mask, v, never), axis)``."""
    fh = jnp.where(mask, p[0], NEVER[0])
    fl = jnp.where(mask, p[1], NEVER[1])
    mh = jnp.min(fh, axis=axis)
    cand = fh == jnp.expand_dims(mh, axis)
    ml = jnp.min(jnp.where(cand, _u(fl), _INT32_MAX), axis=axis)
    return (mh, ml ^ _INT32_MIN)


def reduce_max(p, axis=1):
    mh = jnp.max(p[0], axis=axis)
    cand = p[0] == jnp.expand_dims(mh, axis)
    ml = jnp.max(jnp.where(cand, _u(p[1]), _INT32_MIN), axis=axis)
    return (mh, ml ^ _INT32_MIN)


def argmin_masked(p, mask=None, axis=1):
    """First index of the pair-lexicographic minimum — bitwise the int64
    ``argmin(where(mask, v, never))`` (ties resolve to the lowest index,
    all-masked rows resolve to index 0, exactly like the int64 path)."""
    if mask is None:
        fh, fl = p
    else:
        fh = jnp.where(mask, p[0], NEVER[0])
        fl = jnp.where(mask, p[1], NEVER[1])
    mh = jnp.min(fh, axis=axis, keepdims=True)
    cand = fh == mh
    ml = jnp.min(jnp.where(cand, _u(fl), _INT32_MAX), axis=axis,
                 keepdims=True)
    win = cand & (_u(fl) == ml)
    # first-True index as a masked-iota min: jnp.argmax's index dtype is
    # int64 under x64, which Mosaic cannot lower (and 1-D iota is equally
    # rejected, hence the broadcasted form). `win` has >= 1 True per row.
    idx = jax.lax.broadcasted_iota(I32, win.shape, axis)
    return jnp.min(jnp.where(win, idx, _INT32_MAX), axis=axis)


def mod_pow2(p, m: int):
    """``value % m`` as int32, for a power-of-two ``m`` and value >= 0.
    Exact because 2**32 ≡ 0 (mod m): only the low word contributes.

    >>> import numpy as np
    >>> int(np.asarray(mod_pow2(unpack_np(np.int64([2**33 + 70])), 64))[0])
    6
    """
    if m < 1 or (m & (m - 1)) != 0:
        raise ValueError(f"m must be a positive power of two, got {m}")
    return p[1] & np.int32(m - 1)


# -- int64 conversion -------------------------------------------------------


def pack(p):
    """Pair -> int64 jnp array. Requires x64 to be enabled."""
    hi = p[0].astype(jnp.int64)
    lo = p[1].astype(jnp.int64) & np.int64(_U32_MASK)
    return (hi << 32) | lo


def unpack(x):
    """int64 jnp array -> pair (requires x64 for the input to be i64)."""
    x = jnp.asarray(x)
    hi = (x >> 32).astype(I32)
    lo = jax.lax.bitcast_convert_type(
        (x & np.int64(_U32_MASK)).astype(jnp.uint32), I32)
    return (hi, lo)


def pack_np(hi, lo) -> np.ndarray:
    """Numpy pair -> int64 (host-side; works with x64 off)."""
    return ((np.asarray(hi, np.int64) << 32)
            | (np.asarray(lo, np.int64) & _U32_MASK))


def unpack_np(x):
    """Numpy int64 -> pair of int32 arrays (host-side)."""
    x = np.asarray(x, np.int64)
    hi = (x >> 32).astype(np.int32)
    lo = (x & _U32_MASK).astype(np.uint32).view(np.int32)
    return (hi, lo)

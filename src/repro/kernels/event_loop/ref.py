"""XLA oracle for the event-loop kernel: the serial ``sim._run_events``
next-event loop, vmapped over the flattened replica axis.

``repro.core.sim._run_events`` is the single source of truth for the
simulator's semantics; this wrapper gives it the same batched call
signature as ``ops.run_events`` so the kernel tests can diff the two paths
operand-for-operand (bitwise — both consume the identical counter-based
``fold_in`` draw stream). ``batch.sweep``'s sharded XLA leg reuses it as
its per-shard block, so the oracle and the production fallback are one
code path.
"""
from __future__ import annotations

import functools

import jax

from repro.core.sim import LAT_SAMPLES, _run_events


def run_events_ref(alg, T, N, K, n_events, wl, thread_node, lock_node, *,
                   lat_samples: int = LAT_SAMPLES):
    """Batched XLA reference. ``wl`` is a ``WorkloadOperands`` whose leaves
    all carry a leading replica axis B (locality (B,P,T), zcdf (B,P,kpn),
    edges/think_ns (B,P), active (B,P,T), b_init (B,P,2), cost_rows
    (B,P,8), node_mult (B,P,N), seed (B,)); thread_node (T,) and
    lock_node (K,) broadcast.
    Returns (done (B,T), lat (B,lat_samples), lat_n (B,), t_end (B,),
    nreacq (B,), npass (B,)) — must run under ``enable_x64()``.
    """
    point = functools.partial(_run_events, alg, T, N, K, n_events)

    def one(w):
        return point(w, thread_node, lock_node, lat_samples=lat_samples)

    return jax.vmap(one)(wl)

"""XLA oracle for the event-loop kernel: the serial ``sim._run_events``
next-event loop, vmapped over the flattened replica axis.

``repro.core.sim._run_events`` is the single source of truth for the
simulator's semantics; this wrapper gives it the same batched call
signature as ``ops.run_events`` so the kernel tests can diff the two paths
operand-for-operand (bitwise — both consume the identical counter-based
``fold_in`` draw stream). ``batch.sweep``'s sharded XLA leg reuses it as
its per-shard block, so the oracle and the production fallback are one
code path.
"""
from __future__ import annotations

import functools

import jax

from repro.core.sim import _run_events


def run_events_ref(alg, T, N, K, n_events, locality, b_init, thread_node,
                   lock_node, costs, seed, zcdf):
    """Batched XLA reference. Operands carry a leading replica axis B:
    locality (B,), b_init (B,2), costs (B,8), seed (B,), zcdf (B,K//N);
    thread_node (T,) and lock_node (K,) broadcast. Returns
    (done (B,T), lat (B,LAT), lat_n (B,), t_end (B,), nreacq (B,),
    npass (B,)) — must run under ``enable_x64()``.
    """
    point = functools.partial(_run_events, alg, T, N, K, n_events)

    def one(loc, bi, cst, sd, zc):
        return point(loc, bi, thread_node, lock_node,
                     tuple(cst[j] for j in range(cst.shape[0])), sd, zc)

    return jax.vmap(one)(locality, b_init, costs, seed, zcdf)

"""Pallas TPU kernel: the discrete-event simulator's next-event loop.

``sim._run_events`` is a serial argmin+switch ``fori_loop`` lowered through
XLA: every event re-dispatches a chain of gather/scatter/select HLOs against
HBM-resident state. This kernel keeps ALL per-replica state — the semantic
``Sem`` machine (tails/victim/word, per-thread descriptors), the ``ready``/
``busy``/``op_start`` clocks and the latency ring — resident in VMEM for the
entire ``n_events`` run: one HBM read and one write per replica, replicas
tiled across the first grid axis exactly like ``kernels/alock_tick``.

Layout
  grid = (replica_tiles, event_chunks); the second axis is the innermost
  (sequential) one, so VMEM scratch carries the simulation state from chunk
  to chunk while each chunk streams in its (tile, ev_chunk) slice of the
  precomputed workload draws. Outputs index-map to the same block for every
  chunk and are only flushed to HBM when the tile changes.

Branch dispatch
  ``sim.sem_step``'s ``lax.switch`` over 14 PC branches is re-expressed as
  masked ``jnp.select`` over the PC classes (the ``alock_tick`` pattern):
  per event each replica row computes every branch's update and keeps the
  one selected by its thread's PC. Scatters at per-row indices (lock k,
  thread tid/pred/succ, node) are one-hot masked writes.

Clock representation
  Every 64-bit quantity — the ``ready``/``busy``/``op_start`` clocks, the
  latency ring and the parked-thread ``never`` sentinel — goes through one
  of two interchangeable representations selected by the static ``repr32``
  flag (``ops.py`` resolves it):

  * ``_I64Clocks`` — plain int64 arrays. The fast path for interpret mode
    and XLA-adjacent hosts; callers hold ``enable_x64()``.
  * ``_PairClocks`` — hi/lo int32 pairs with carry-correct add/sub and
    lexicographic compare/argmin (``i32pair.py``). Mosaic has no 64-bit
    vector registers, so this is the *native-TPU* representation; it also
    runs with x64 entirely off. Bitwise-equal to the i64 path (the
    ``tests/test_event_loop_native_repr.py`` differential suite).

  Under the pair representation the latency ring is written as a masked
  one-hot accumulate over the ``lat_samples`` axis (2D
  ``broadcasted_iota`` == slot, then select) — bitwise-identical to the
  per-row scatter but expressible in Mosaic, which rejects per-row
  dynamic scatters against VMEM state. The i64 fast path keeps the
  O(1)-per-event scatter (the one-hot form costs O(lat_samples) lane-ops
  per event, which would tax the interpret-mode CI/perfcheck runs for no
  benefit). The ring-overflow tests hold the two forms identical.

Randomness + workload operands
  The XLA loop draws from ``jax.random.fold_in(key, i)`` per event. The
  raw draws depend only on (seed, i) — never on simulation state — so
  ``ops.py`` precomputes the stream with the *same* jax.random calls and
  feeds the kernel three streams: the locality uniform (f32), the
  remote-node offset and the phase-resolved within-node Zipf offset. The
  thread-dependent half of the locality draw (``u < locality[phase, tid]``)
  runs here, against the per-phase per-thread locality operand, because
  ``tid`` is the runtime argmin of the ready clocks. Phases are resolved
  per event from the ``edges`` operand (phase = sum(i >= edges) - 1);
  the per-phase ``active`` mask parks downed threads by excluding them
  from the ready-time argmin, ``think_ns[phase]`` replaces the static
  think cost, and the event's cost scalars / ALock budgets / fail-slow
  node multipliers are one-hot phase selections from the
  ``cost_rows (P, 8)`` / ``b_init (P, 2)`` / ``node_mult (P, N)``
  operands (single-phase specs keep the flat row-0 fast path). Per-seed
  results are bitwise-equal to the XLA path, which the tier-1
  equivalence tests assert. The semantic state stays int32 everywhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl

from repro.core import machine as mc
from repro.core.cost_model import N_COST_ROWS
from repro.core.sim import (LAT_SAMPLES, OP_CS, OP_LOCAL, OP_LOOP, OP_POLL,
                            OP_RDMA, OP_THINK)
from repro.kernels.event_loop import i32pair as p32
from repro.traffic.metrics import COMPLETED, DROPPED, IN_SERVICE

I32 = jnp.int32
I64 = jnp.int64


def _iota(shape, dim):
    """2D index grid — Mosaic rejects 1D iota, so every index vector in
    the kernel is built broadcasted."""
    return lax.broadcasted_iota(I32, shape, dim)


#: python-int constants (machine PCs, opcodes, dims) are weak-typed: under
#: x64 they widen `jnp.where` branches to int64, which Mosaic cannot lower.
#: Every such constant is pinned at its use site (repro.analysis rule M001).
_I = np.int32


def _select(conds, vals, default):
    """``jnp.select`` semantics (first true condition wins) as a reversed
    ``jnp.where`` chain — jnp.select lowers through an argmax whose index
    dtype is int64 under x64, poisoning the Mosaic kernel jaxpr."""
    acc = default
    for c, v in zip(reversed(conds), reversed(vals)):
        acc = jnp.where(c, v, acc)
    return acc


class _I64Clocks:
    """Clock values are plain int64 arrays (interpret / XLA fast path)."""
    nrefs = 1

    @staticmethod
    def read(refs):
        return refs[0][...]

    @staticmethod
    def write(refs, v):
        refs[0][...] = v

    @staticmethod
    def zeros(shape):
        return jnp.zeros(shape, I64)

    @staticmethod
    def full_m1(shape):
        return jnp.full(shape, -1, I64)

    @staticmethod
    def where(c, a, b):
        return jnp.where(c, a, b)

    @staticmethod
    def col(v):
        return v[:, None]

    @staticmethod
    def gather(oh, v):
        """One-hot gather along axis 1; the sum dtype is pinned (under x64
        ``jnp.sum`` would otherwise widen and poison carry dtypes)."""
        return jnp.sum(jnp.where(oh, v, 0), axis=1, dtype=v.dtype)

    @staticmethod
    def add_i32(v, d):
        return v + d

    @staticmethod
    def sub(a, b):
        return a - b

    @staticmethod
    def max2(a, b):
        return jnp.maximum(a, b)

    @staticmethod
    def le(a, b):
        return a <= b

    @staticmethod
    def reduce_min_masked(v, mask):
        return jnp.min(jnp.where(mask, v, jnp.iinfo(jnp.int64).max), axis=1)

    @staticmethod
    def reduce_max(v):
        return jnp.max(v, axis=1)

    @staticmethod
    def argmin_masked(v, mask=None):
        if mask is not None:
            v = jnp.where(mask, v, jnp.iinfo(jnp.int64).max)
        return jnp.argmin(v, axis=1).astype(I32)

    @staticmethod
    def is_never(v):
        return v == jnp.iinfo(jnp.int64).max


class _PairClocks:
    """Clock values are (hi, lo) int32 pairs — the Mosaic-lowerable
    representation (see ``i32pair.py``); needs no x64 anywhere."""
    nrefs = 2

    @staticmethod
    def read(refs):
        return (refs[0][...], refs[1][...])

    @staticmethod
    def write(refs, v):
        refs[0][...] = v[0]
        refs[1][...] = v[1]

    zeros = staticmethod(p32.pzeros)

    @staticmethod
    def full_m1(shape):
        return p32.pfull(shape, -1)

    where = staticmethod(p32.pwhere)

    @staticmethod
    def col(v):
        return (v[0][:, None], v[1][:, None])

    gather = staticmethod(p32.pgather)
    add_i32 = staticmethod(p32.padd_i32)
    sub = staticmethod(p32.psub)
    max2 = staticmethod(p32.pmax2)
    le = staticmethod(p32.ple)
    reduce_min_masked = staticmethod(p32.reduce_min_masked)
    reduce_max = staticmethod(p32.reduce_max)
    argmin_masked = staticmethod(p32.argmin_masked)

    @staticmethod
    def is_never(v):
        return p32.peq(v, p32.NEVER)


def event_loop_kernel(*refs, alg: str, T: int, N: int, K: int, P: int,
                      n_events: int, ev_chunk: int,
                      lat_samples: int = LAT_SAMPLES, repr32: bool = False,
                      R: int = 0):
    """One (replica_tile, event_chunk) grid step.

    ``refs`` arrive flat from ``pl.pallas_call`` — 12 inputs (plus the
    open-loop arrival rows when ``R > 0``, the read coin/probability rows
    for ``alock-rw`` and the rack row for ``hlock``), then the outputs and
    scratch whose *count* depends on the clock representation (one ref per
    clock buffer for i64, an (hi, lo) pair for i32) — and are regrouped
    here from the static ``repr32`` / ``R`` / alg flags. ``R == 0`` is the
    closed loop and parses/traces exactly the pre-traffic program (every
    ``if R > 0`` block below is python-level dead code then); likewise the
    ``is_hl`` / ``is_rw`` blocks are dead for every other algorithm, so
    alock/mcs/spinlock trace the exact pre-topology program.

    s_t0/s_t1 are the two cohort tails for alock (and its hlock/alock-rw
    variants); for mcs/spinlock s_t0 is the lock word and s_t1/s_vic stay
    zero (those PCs are unreachable). alock-rw adds an s_word scratch
    holding per-lock reader counts.
    """
    C = _PairClocks if repr32 else _I64Clocks
    nc = C.nrefs
    is_hl = alg == "hlock"
    is_rw = alg == "alock-rw"
    # hlock and alock-rw run the full ALock tail/victim/budget machinery;
    # their extra refs (read coin + read_frac row, rack row, reader-count
    # scratch) are python-gated so every other algorithm's ref layout —
    # and traced program — is byte-identical to the pre-topology kernel
    (u1_ref, r2_ref, r3_ref) = refs[:3]
    pos = 3
    if is_rw:
        u4_ref = refs[pos]                  # reader/writer coin stream
        pos += 1
    (edges_ref, think_ref, locp_ref) = refs[pos:pos + 3]
    pos += 3
    if is_rw:
        readf_ref = refs[pos]               # per-phase read probabilities
        pos += 1
    (actp_ref, binit_ref, costs_ref, nmult_ref, tn_ref,
     ln_ref) = refs[pos:pos + 6]
    pos += 6
    if is_hl:
        rack_ref = refs[pos]                # per-node rack ids
        pos += 1
    if R > 0:
        arr_refs = refs[pos:pos + nc]
        tok_ref, tokcum_ref, qcap_ref = refs[pos + nc:pos + nc + 3]
        pos += nc + 3
    rest = refs[pos:]
    done_ref = rest[0]
    lat_refs = rest[1:1 + nc]
    latn_ref = rest[1 + nc]
    tend_refs = rest[2 + nc:2 + 2 * nc]
    reacq_ref, npass_ref = rest[2 + 2 * nc:4 + 2 * nc]
    pos = 4 + 2 * nc
    if R > 0:
        wq_refs = rest[pos:pos + nc]
        soj_refs = rest[pos + nc:pos + 2 * nc]
        rstat_ref = rest[pos + 2 * nc]
        pos += 2 * nc + 1
    scr = rest[pos:]
    (s_t0, s_t1, s_vic, s_pc, s_bud, s_nxt, s_prev, s_tgt, s_coh) = scr[:9]
    pos = 9
    if is_rw:
        s_word = scr[pos]                   # per-lock reader counts
        pos += 1
    ready_refs = scr[pos:pos + nc]
    busy_refs = scr[pos + nc:pos + 2 * nc]
    opst_refs = scr[pos + 2 * nc:pos + 3 * nc]
    if R > 0:
        s_curreq, s_arrptr, s_qlen = scr[pos + 3 * nc:pos + 3 * nc + 3]

    is_alock = alg in ("alock", "hlock", "alock-rw")
    is_spin = alg == "spinlock"
    j = pl.program_id(1)
    tile = s_pc.shape[0]
    kpn = K // N

    @pl.when(j == 0)
    def _init():
        # fresh replicas == sim.init_sem + zeroed clocks/accounting
        zrefs = (s_t0, s_t1, s_vic, s_nxt, s_prev, s_tgt, s_coh,
                 done_ref, latn_ref, reacq_ref, npass_ref)
        if is_rw:
            zrefs = zrefs + (s_word,)
        if R > 0:
            zrefs = zrefs + (rstat_ref, s_arrptr, s_qlen)
        for ref in zrefs:
            ref[...] = jnp.zeros(ref.shape, ref.dtype)
        s_pc[...] = jnp.full((tile, T), mc.NCS, I32)
        s_bud[...] = jnp.full((tile, T), -1, I32)
        for crefs, shape in ((ready_refs, (tile, T)), (busy_refs, (tile, N)),
                             (opst_refs, (tile, T))):
            C.write(crefs, C.zeros(shape))
        C.write(lat_refs, C.full_m1((tile, lat_samples)))
        if R > 0:
            s_curreq[...] = jnp.full((tile, T), -1, I32)
            C.write(wq_refs, C.full_m1((tile, R)))
            C.write(soj_refs, C.full_m1((tile, R)))

    u1s = u1_ref[...]                               # (tile, ev_chunk) f32
    r2s = r2_ref[...].astype(I32)
    r3s = r3_ref[...].astype(I32)
    edges = edges_ref[...].astype(I32)              # (tile, P)
    think = think_ref[...].astype(I32)              # (tile, P)
    # per-phase payloads arrive flattened (tile, P*…); P and T are static
    locp = locp_ref[...].reshape(tile, P, T)        # f32
    actp = actp_ref[...].astype(I32).reshape(tile, P, T)
    binitp = binit_ref[...].astype(I32).reshape(tile, P, 2)
    cstp = costs_ref[...].astype(I32).reshape(tile, P, N_COST_ROWS)
    nmp = nmult_ref[...].reshape(tile, P, N)        # f32 fail-slow mults
    tn = jnp.broadcast_to(tn_ref[...].astype(I32), (tile, T))
    ln = jnp.broadcast_to(ln_ref[...].astype(I32), (tile, K))
    if is_rw:
        u4s = u4_ref[...]                           # (tile, ev_chunk) f32
        readfp = readf_ref[...].reshape(tile, P, T)  # f32 read probs
    if is_hl:
        rk = rack_ref[...].astype(I32)              # (tile, N) rack ids
    if R > 0:
        # open-loop arrival rows: times (clock), token admit mask +
        # exclusive prefix count, per-request queue bound (all (tile, R))
        arr = C.read(arr_refs)
        tok = tok_ref[...].astype(I32)
        tokcum = tokcum_ref[...].astype(I32)
        qcap = qcap_ref[...].astype(I32)
        rio = _iota((tile, R), 1)

    tids = _iota((tile, T), 1)
    kio = _iota((tile, K), 1)
    nio = _iota((tile, N), 1)
    pio = _iota((tile, P), 1)
    if repr32:
        sio = _iota((tile, lat_samples), 1)   # ring one-hot (Mosaic path)
    else:
        rows = jnp.arange(tile)               # ring scatter (fast path)

    def gat_t(arr, idx):
        """(tile, T) gathered at per-row thread idx -> (tile,). The sum
        dtype is pinned: under x64 ``jnp.sum(int32)`` would widen to the
        default int and poison every downstream carry dtype."""
        return jnp.sum(jnp.where(tids == idx[:, None], arr,
                                 arr.dtype.type(0)), axis=1,
                       dtype=arr.dtype)

    def gat_k(arr, idx):
        return jnp.sum(jnp.where(kio == idx[:, None], arr,
                                 arr.dtype.type(0)), axis=1,
                       dtype=arr.dtype)

    state = (s_t0[...], s_t1[...], s_vic[...], s_pc[...], s_bud[...],
             s_nxt[...], s_prev[...], s_tgt[...], s_coh[...],
             C.read(ready_refs), C.read(busy_refs), C.read(opst_refs),
             done_ref[...], C.read(lat_refs), latn_ref[...][:, 0],
             reacq_ref[...][:, 0], npass_ref[...][:, 0])
    if R > 0:
        def gat_r(arr, idx):
            return jnp.sum(jnp.where(rio == idx[:, None], arr,
                                     arr.dtype.type(0)), axis=1,
                           dtype=arr.dtype)

        state = state + (rstat_ref[...], s_curreq[...],
                         s_arrptr[...][:, 0], s_qlen[...][:, 0],
                         C.read(wq_refs), C.read(soj_refs))
    if is_rw:
        # reader counts ride at the tail of the carry so every existing
        # unpack position stays fixed for the other algorithms
        state = state + (s_word[...],)

    def step(e, st):
        if is_rw:
            st_wrd = st[-1]
            wrd = st_wrd
            st = st[:-1]
        if R > 0:
            (t0, t1, vic, pc, bud, nxt, prv, tgt, coh, ready, busy, opst,
             done, lat, latn, reacq, npass,
             rstat, curreq, arrptr, qlen, wq, soj) = st
            sem_old = (t0, t1, vic, pc, bud, nxt, prv, tgt, coh)
        else:
            (t0, t1, vic, pc, bud, nxt, prv, tgt, coh, ready, busy, opst,
             done, lat, latn, reacq, npass) = st

        # -- phase resolve (pure function of the global event index) -------
        gi = j * ev_chunk + e
        if P > 1:
            ph = jnp.sum((gi >= edges).astype(I32), axis=1,
                         dtype=I32) - 1              # (tile,)
            ohP = pio == ph[:, None]
            act_row = jnp.sum(jnp.where(ohP[:, :, None], actp, _I(0)),
                              axis=1, dtype=I32)
            loc_row = jnp.sum(jnp.where(ohP[:, :, None], locp,
                                        np.float32(0)),
                              axis=1, dtype=jnp.float32)
            think_e = jnp.sum(jnp.where(ohP, think, _I(0)), axis=1,
                              dtype=I32)
            # phase-indexed cost rows + ALock budgets (sum dtypes pinned,
            # same x64 caveat as gat_t)
            binit = jnp.sum(jnp.where(ohP[:, :, None], binitp, _I(0)),
                            axis=1, dtype=I32)       # (tile, 2)
            cst = jnp.sum(jnp.where(ohP[:, :, None], cstp, _I(0)), axis=1,
                          dtype=I32)                 # (tile, 8)
            nm_row = jnp.sum(jnp.where(ohP[:, :, None], nmp, np.float32(0)),
                             axis=1, dtype=jnp.float32)   # (tile, N)
            if is_rw:
                rf_row = jnp.sum(jnp.where(ohP[:, :, None], readfp,
                                           np.float32(0)),
                                 axis=1, dtype=jnp.float32)   # (tile, T)

            # phase boundary: rejoining threads resume from the cluster's
            # current clock (mirror of the XLA loop's rejoin bump)
            ohPp = pio == jnp.maximum(ph - _I(1), _I(0))[:, None]
            was_act = jnp.sum(jnp.where(ohPp[:, :, None], actp, _I(0)),
                              axis=1, dtype=I32)
            rejoin = (jnp.any(gi == edges, axis=1)[:, None]
                      & (act_row != 0) & (was_act == 0))
            cont_min = C.reduce_min_masked(ready,
                                           (act_row != 0) & (was_act != 0))
            now_min = C.where(C.is_never(cont_min),
                              C.reduce_min_masked(ready, act_row != 0),
                              cont_min)
            ready = C.where(rejoin, C.max2(ready, C.col(now_min)), ready)
            actm = act_row != 0
        else:
            # single phase: the flat PR-2 hot path, no phase machinery
            # (lowering guarantees P == 1 operands are all-active)
            loc_row = locp[:, 0, :]
            think_e = think[:, 0]
            binit = binitp[:, 0]
            cst = cstp[:, 0]
            nm_row = nmp[:, 0, :]
            if is_rw:
                rf_row = readfp[:, 0, :]
            actm = None
        if R > 0:
            # idle threads (NCS, no request bound) wake at the earliest
            # available arrival instead of re-arming; busy threads keep
            # their own clocks (mirror of sim._run_events' elig)
            pend = (pc == mc.NCS) & (curreq < _I(0))
            avail = (rstat == _I(0)) & (tok == _I(1))
            next_arr = C.reduce_min_masked(arr, avail)
            elig = C.where(pend, C.max2(ready, C.col(next_arr)), ready)
        else:
            elig = ready
        if actm is not None:
            tid = C.argmin_masked(elig, actm)
        else:
            tid = C.argmin_masked(elig)
        ohT = tids == tid[:, None]
        now = C.gather(ohT, elig)
        me = tid + 1
        p = gat_t(pc, tid)
        tg = gat_t(tgt, tid)
        ch = gat_t(coh, tid)
        bd = gat_t(bud, tid)
        nx = gat_t(nxt, tid)
        pv = gat_t(prv, tid)
        ohK = kio == tg[:, None]
        mynode = gat_t(tn, tid)

        # -- workload draw (precomputed stream; NCS branch consumes it) ----
        u1e = lax.dynamic_index_in_dim(u1s, e, 1, keepdims=False)
        r2e = lax.dynamic_index_in_dim(r2s, e, 1, keepdims=False)
        r3e = lax.dynamic_index_in_dim(r3s, e, 1, keepdims=False)
        # thread-dependent half of the locality draw: same f32 compare as
        # the XLA loop's uniform(k1) < locality[ph, tid]
        loc_t = jnp.sum(jnp.where(ohT, loc_row, np.float32(0)), axis=1,
                        dtype=jnp.float32)
        ge = u1e < loc_t
        other = (mynode + _I(1) + r2e) % _I(N)
        node_w = jnp.where(ge, mynode, other).astype(I32)
        new_t = node_w * kpn + r3e
        if is_hl:
            # hierarchical cohort: LOCAL means same *rack*, not same node
            # (one-hot rack gathers of the XLA loop's wl.rack[] compares)
            rk_w = jnp.sum(jnp.where(nio == node_w[:, None], rk, _I(0)),
                           axis=1, dtype=I32)
            rk_me = jnp.sum(jnp.where(nio == mynode[:, None], rk, _I(0)),
                            axis=1, dtype=I32)
            new_c = (rk_w != rk_me).astype(I32)
        else:
            new_c = (node_w != mynode).astype(I32)
        if is_rw:
            # reader/writer coin: same f32 compare as the XLA loop's
            # uniform(k4) < read_frac[ph, tid]
            u4e = lax.dynamic_index_in_dim(u4s, e, 1, keepdims=False)
            rf_t = jnp.sum(jnp.where(ohT, rf_row, np.float32(0)), axis=1,
                           dtype=jnp.float32)
            new_r = u4e < rf_t

        if R > 0:
            live = jnp.logical_not(C.is_never(now))
            pend_tid = jnp.sum(jnp.where(ohT, pend.astype(I32), _I(0)),
                               axis=1, dtype=I32) == _I(1)
            # -- arrival ingestion: every request with arr <= now either
            # joins the wait queue or drops (token reject / queue full);
            # `rank` orders token-admitted newcomers for exact tail drop.
            # Integer-exact, so the one-hot forms here agree bitwise with
            # the XLA loop's dynamic gathers/scatters.
            arrived = C.le(arr, C.col(now))
            cnt_now = jnp.where(
                live, jnp.sum(arrived.astype(I32), axis=1, dtype=I32),
                arrptr)
            newly = ((rio >= arrptr[:, None])
                     & (rio < cnt_now[:, None]))
            rank = tokcum - gat_r(tokcum, arrptr)[:, None]
            join = (newly & (tok == _I(1))
                    & (rank < qcap - qlen[:, None]))
            rstat = jnp.where(newly & ~join, _I(DROPPED), rstat)
            qlen = qlen + jnp.sum(join.astype(I32), axis=1, dtype=I32)
            arrptr = cnt_now
            # -- dispatch: an idle selected thread takes the FIFO head --
            queued = (rstat == _I(0)) & (rio < arrptr[:, None])
            head = jnp.min(jnp.where(queued, rio,
                                     _I(np.iinfo(np.int32).max)), axis=1)
            do_disp = live & pend_tid & jnp.any(queued, axis=1)
            hd = jnp.minimum(head, _I(R - 1))
            ohR = rio == hd[:, None]
            dm = do_disp[:, None]
            rstat = jnp.where(ohR & dm, _I(IN_SERVICE), rstat)
            curreq = jnp.where(ohT & dm, hd[:, None], curreq)
            wqv = C.sub(now, C.gather(ohR, arr))
            wq = C.where(ohR & dm, C.col(wqv), wq)
            qlen = qlen - do_disp.astype(I32)
            # an idle thread with nothing to take makes no machine step
            step_ok = live & (~pend_tid | do_disp)

        # -- PC class masks (exactly one true per row) ---------------------
        is_ncs = p == mc.NCS
        is_swap = p == mc.SWAP
        is_wn = p == mc.WRITE_NEXT
        is_sb = p == mc.SPIN_BUDGET
        is_sv = p == mc.SET_VICTIM
        is_svr = p == mc.SET_VICTIM_R
        is_pw = p == mc.PET_WAIT
        is_pwr = p == mc.PET_WAIT_R
        is_cs = p == mc.CS
        is_rc = p == mc.REL_CAS
        is_sn = p == mc.SPIN_NEXT
        is_ps = p == mc.PASS
        is_slc = p == mc.SL_CAS
        is_slr = p == mc.SL_REL
        if is_rw:
            is_rdt = p == mc.RD_TRY
            is_rdc = p == mc.RD_CS
            is_rdr = p == mc.RD_REL
            is_wd = p == mc.WR_DRAIN

        Bc = jnp.where(ch == 0, binit[:, 0], binit[:, 1])
        tail_c = jnp.where(ch == 0, gat_k(t0, tg), gat_k(t1, tg))
        tail_o = jnp.where(ch == 0, gat_k(t1, tg), gat_k(t0, tg))
        wv = gat_k(t0, tg)            # lock word at target (mcs/spinlock)
        vk = gat_k(vic, tg)
        pred = pv - 1
        succ = nx - 1
        oh_pred = tids == pred[:, None]
        oh_succ = tids == succ[:, None]
        has_succ = nx != 0
        prev_val = tail_c if is_alock else wv
        empty = prev_val == 0
        solo = (tail_c if is_alock else wv) == me
        free = wv == 0
        can = (tail_o == 0) | (vk != ch)
        newb = (bd - 1) if is_alock else jnp.ones_like(bd)
        if is_rw:
            # reader entry with writer preference: both cohort tails empty
            # (mirror of machine.f_rd_try); drain waits for the reader
            # count at the target to reach zero
            can_rd = (tail_c == 0) & (tail_o == 0)
            wdv = gat_k(wrd, tg)

        # -- lock word / tails / victim ------------------------------------
        if is_alock:
            m0 = (is_swap & (ch == 0))[:, None] & ohK
            m1 = (is_swap & (ch == 1))[:, None] & ohK
            t0 = jnp.where(m0, me[:, None], t0)
            t1 = jnp.where(m1, me[:, None], t1)
            r0 = (is_rc & solo & (ch == 0))[:, None] & ohK
            r1 = (is_rc & solo & (ch == 1))[:, None] & ohK
            t0 = jnp.where(r0, _I(0), t0)
            t1 = jnp.where(r1, _I(0), t1)
            vmask = (is_sv | is_svr)[:, None] & ohK
            vic = jnp.where(vmask, ch[:, None], vic)
        else:
            t0 = jnp.where(is_swap[:, None] & ohK, me[:, None], t0)
            t0 = jnp.where((is_rc & solo)[:, None] & ohK, _I(0), t0)
            t0 = jnp.where((is_slc & free)[:, None] & ohK, me[:, None], t0)
            t0 = jnp.where(is_slr[:, None] & ohK, _I(0), t0)
        if is_rw:
            # reader count at the target: +1 on a successful RD_TRY, -1 on
            # RD_REL (one-hot forms of word.at[k].add)
            wrd = wrd + jnp.where((is_rdt & can_rd)[:, None] & ohK, _I(1),
                                  _I(0))
            wrd = wrd - jnp.where(is_rdr[:, None] & ohK, _I(1), _I(0))

        # -- per-thread descriptors ----------------------------------------
        prv = jnp.where(is_swap[:, None] & ohT, prev_val[:, None], prv)
        nxt = jnp.where(is_ncs[:, None] & ohT, _I(0), nxt)
        nxt = jnp.where(is_wn[:, None] & oh_pred, me[:, None], nxt)
        bud_tid_val = _select([is_ncs, is_swap, is_pwr],
                              [jnp.full_like(bd, -1), Bc, Bc], bd)
        swap_bud = (is_swap & empty) if is_alock else jnp.zeros_like(is_swap)
        bud_tid_m = is_ncs | swap_bud | (is_pwr & can)
        bud = jnp.where(bud_tid_m[:, None] & ohT, bud_tid_val[:, None], bud)
        bud = jnp.where(is_ps[:, None] & oh_succ, newb[:, None], bud)
        tgt = jnp.where(is_ncs[:, None] & ohT, new_t[:, None], tgt)
        coh = jnp.where(is_ncs[:, None] & ohT, new_c[:, None], coh)

        # -- next PC (the lax.switch, as one select over PC classes) -------
        # a writer's every CS entry detours through the reader drain (rw)
        ecs = mc.WR_DRAIN if is_rw else mc.CS
        if is_rw:
            first_val = jnp.where(new_r, _I(mc.RD_TRY), _I(mc.SWAP))
        else:
            first_val = jnp.full_like(p, mc.SL_CAS if is_spin else mc.SWAP)
        if is_alock:
            pc_swap = jnp.where(empty, _I(mc.SET_VICTIM), _I(mc.WRITE_NEXT))
            pc_sb = jnp.where(bd == -1, _I(mc.SPIN_BUDGET),
                              jnp.where(bd == 0, _I(mc.SET_VICTIM_R),
                                        _I(ecs)))
        else:
            pc_swap = jnp.where(empty, _I(mc.CS), _I(mc.WRITE_NEXT))
            pc_sb = jnp.where(bd == -1, _I(mc.SPIN_BUDGET), _I(mc.CS))
        pc_conds = [is_ncs, is_swap, is_wn, is_sb, is_sv, is_svr, is_pw,
                    is_pwr, is_cs, is_rc, is_sn, is_ps, is_slc, is_slr]
        pc_vals = [first_val, pc_swap,
                   jnp.full_like(p, mc.SPIN_BUDGET), pc_sb,
                   jnp.full_like(p, mc.PET_WAIT),
                   jnp.full_like(p, mc.PET_WAIT_R),
                   jnp.where(can, _I(ecs), _I(mc.PET_WAIT)),
                   jnp.where(can, _I(ecs), _I(mc.PET_WAIT_R)),
                   jnp.full_like(p, mc.SL_REL if is_spin else mc.REL_CAS),
                   jnp.where(solo, _I(mc.NCS), _I(mc.SPIN_NEXT)),
                   jnp.where(has_succ, _I(mc.PASS), _I(mc.SPIN_NEXT)),
                   jnp.full_like(p, mc.NCS),
                   jnp.where(free, _I(mc.CS), _I(mc.SL_CAS)),
                   jnp.full_like(p, mc.NCS)]
        if is_rw:
            pc_conds += [is_rdt, is_rdc, is_rdr, is_wd]
            pc_vals += [jnp.where(can_rd, _I(mc.RD_CS), _I(mc.RD_TRY)),
                        jnp.full_like(p, mc.RD_REL),
                        jnp.full_like(p, mc.NCS),
                        jnp.where(wdv == 0, _I(mc.CS), _I(mc.WR_DRAIN))]
        new_pc = _select(pc_conds, pc_vals, p).astype(I32)
        pc = jnp.where(ohT, new_pc[:, None], pc)
        if R > 0:
            # no-op events (drained stream / idle thread with an empty
            # queue) keep the semantic machine frozen — the exact analogue
            # of the XLA loop's step_ok tree_map over sem2
            sm = step_ok[:, None]
            (t0, t1, vic, pc, bud, nxt, prv, tgt, coh) = tuple(
                jnp.where(sm, n, o) for n, o in
                zip((t0, t1, vic, pc, bud, nxt, prv, tgt, coh), sem_old))
            if is_rw:
                wrd = jnp.where(sm, wrd, st_wrd)

        # -- cost opcode + RNIC node (sim._step_fns' cost functions) -------
        lnode = gat_k(ln, tg)
        pred_node = gat_t(tn, pred)
        succ_node = gat_t(tn, succ)
        if is_hl:
            # three-tier cost: own node -> shared memory, same rack -> the
            # cheap loopback/rack fabric, cross rack -> full RDMA (mirror
            # of sim._step_fns._tiered, one-hot rack gathers)
            def tiered(nd):
                rk_n = jnp.sum(jnp.where(nio == nd[:, None], rk, _I(0)),
                               axis=1, dtype=I32)
                return jnp.where(nd == mynode, _I(OP_LOCAL),
                                 jnp.where(rk_n == rk_me, _I(OP_LOOP),
                                           _I(OP_RDMA)))

            lock_code = tiered(lnode)
            wn_code = tiered(pred_node)
            ps_code = tiered(succ_node)
        elif is_alock:
            lock_code = jnp.where(ch == 0, _I(OP_LOCAL), _I(OP_RDMA))
            wn_code = jnp.where(pred_node == mynode, _I(OP_LOCAL),
                                _I(OP_RDMA))
            ps_code = jnp.where(succ_node == mynode, _I(OP_LOCAL),
                                _I(OP_RDMA))
        else:
            lock_code = jnp.where(lnode == mynode, _I(OP_LOOP), _I(OP_RDMA))
            wn_code = jnp.where(pred_node == mynode, _I(OP_LOOP),
                                _I(OP_RDMA))
            ps_code = jnp.where(succ_node == mynode, _I(OP_LOOP),
                                _I(OP_RDMA))
        lock_m = (is_swap | is_sv | is_svr | is_pw | is_pwr | is_rc
                  | is_slc | is_slr)
        cs_m = is_cs
        if is_rw:
            # reader entry/release and the writer drain are lock-word ops;
            # the reader CS is an OP_CS like the writer's
            lock_m = lock_m | is_rdt | is_rdr | is_wd
            cs_m = cs_m | is_rdc
        code = _select(
            [is_ncs, is_wn, is_sb, cs_m, is_sn, is_ps, lock_m],
            [jnp.full_like(p, OP_THINK),
             wn_code,
             jnp.where(bd == -1, _I(OP_POLL), _I(OP_LOCAL)),
             jnp.full_like(p, OP_CS),
             jnp.where(has_succ, _I(OP_LOCAL), _I(OP_POLL)),
             ps_code,
             lock_code], jnp.full_like(p, 0)).astype(I32)
        tnode = _select([is_wn, is_ps, lock_m],
                        [pred_node, succ_node, lnode],
                        jnp.full_like(p, 0)).astype(I32)

        # -- cost application (identical int arithmetic to _run_events) ----
        # node_mult fail-slow scaling mirrors sim._scale_cost bitwise:
        # f32 multiply of ints < 2^24 is exact, round-to-nearest, back to
        # i32 — svc/wire take the target card's multiplier, dt_plain the
        # calling thread's node's
        is_rdma = (code == OP_RDMA) | (code == OP_LOOP)
        if R > 0:
            is_rdma = is_rdma & step_ok
        ohN = nio == tnode[:, None]
        nm_t = jnp.sum(jnp.where(ohN, nm_row, np.float32(0)), axis=1,
                       dtype=jnp.float32)
        ohMy = nio == mynode[:, None]
        nm_my = jnp.sum(jnp.where(ohMy, nm_row, np.float32(0)), axis=1,
                        dtype=jnp.float32)
        svc = jnp.round(jnp.where(code == OP_LOOP, cst[:, 5], cst[:, 4])
                        .astype(jnp.float32) * nm_t).astype(I32)
        wire = jnp.round(jnp.where(code == OP_LOOP, cst[:, 7], cst[:, 6])
                         .astype(jnp.float32) * nm_t).astype(I32)
        busy_t = C.gather(ohN, busy)
        start = C.max2(now, busy_t)
        fin = C.add_i32(start, svc)
        busy = C.where(is_rdma[:, None] & ohN, C.col(fin), busy)
        dt_plain = jnp.round(_select(
            [code == OP_LOCAL, code == OP_POLL, code == OP_CS,
             code == OP_THINK],
            [cst[:, 0], cst[:, 1], cst[:, 2], think_e], cst[:, 0])
            .astype(jnp.float32) * nm_my).astype(I32)
        new_ready = C.where(is_rdma, C.add_i32(fin, wire),
                            C.add_i32(now, dt_plain))
        if R > 0:
            ready = C.where(ohT & sm, C.col(new_ready), ready)
        else:
            ready = C.where(ohT, C.col(new_ready), ready)

        # -- completion accounting (latency ring, counters) ----------------
        fin_m = is_rc | is_ps | is_slr
        if is_rw:
            # a reader's RD_REL decrement is its release — it completes an
            # acquisition exactly like a writer's REL_CAS/PASS
            fin_m = fin_m | is_rdr
        finished = fin_m & (new_pc == mc.NCS)
        if R > 0:
            finished = finished & step_ok
        lat_val = C.sub(now, C.gather(ohT, opst))
        slot = latn % _I(lat_samples)
        if repr32:
            # masked one-hot accumulate over the sample axis — bitwise
            # the scatter below, but expressible in Mosaic (which rejects
            # per-row dynamic scatters against VMEM state)
            ohS = (sio == slot[:, None]) & finished[:, None]
            lat = C.where(ohS, C.col(lat_val), lat)
        else:
            # interpret/XLA fast path: the O(1)-per-event scatter (the
            # one-hot form costs O(lat_samples) lane-ops per event)
            lat = lat.at[rows, slot].set(
                jnp.where(finished, lat_val, lat[rows, slot]))
        latn = latn + finished.astype(I32)
        done = done + jnp.where(ohT & finished[:, None], _I(1), _I(0))
        if R > 0:
            opst = C.where((is_ncs & step_ok)[:, None] & ohT,
                           C.col(new_ready), opst)
            reacq = reacq + (is_sb & (new_pc == mc.SET_VICTIM_R)
                             & step_ok).astype(I32)
            npass = npass + (is_ps & step_ok).astype(I32)
            # -- departure: the finishing release frees the thread and
            # stamps the request's sojourn at the step's completion time
            req = gat_t(curreq, tid)
            comp = finished & (req >= _I(0))
            rq = jnp.maximum(req, _I(0))
            ohRq = rio == rq[:, None]
            cm = comp[:, None]
            sojv = C.sub(new_ready, C.gather(ohRq, arr))
            soj = C.where(ohRq & cm, C.col(sojv), soj)
            rstat = jnp.where(ohRq & cm, _I(COMPLETED), rstat)
            curreq = jnp.where(ohT & cm, _I(-1), curreq)
            new_st = (t0, t1, vic, pc, bud, nxt, prv, tgt, coh, ready,
                      busy, opst, done, lat, latn, reacq, npass,
                      rstat, curreq, arrptr, qlen, wq, soj)
        else:
            opst = C.where(is_ncs[:, None] & ohT, C.col(new_ready), opst)
            reacq = reacq + (is_sb
                             & (new_pc == mc.SET_VICTIM_R)).astype(I32)
            npass = npass + is_ps.astype(I32)
            new_st = (t0, t1, vic, pc, bud, nxt, prv, tgt, coh, ready,
                      busy, opst, done, lat, latn, reacq, npass)
        if is_rw:
            new_st = new_st + (wrd,)
        return new_st

    # ragged final chunk: bound the loop at the true remaining event count
    # instead of running ev_chunk - (n_events % ev_chunk) masked no-op
    # steps through the whole state tree
    nev_here = jnp.minimum(_I(ev_chunk), _I(n_events) - j * _I(ev_chunk))
    if repr32:
        # explicit i32-counter while_loop: under x64, fori_loop's induction
        # variable is int64 — the one 64-bit aval Mosaic would still see in
        # this kernel. The i64 fast path keeps the fori_loop below (its
        # traced i32 bound keeps the induction variable i32 there too).
        carry = lax.while_loop(
            lambda c: c[0] < nev_here,
            lambda c: (c[0] + _I(1), step(c[0], c[1])),
            (jnp.zeros((), I32), state))
        state = carry[1]
    else:
        state = lax.fori_loop(_I(0), nev_here, step, state)
    if is_rw:
        s_word[...] = state[-1]
        state = state[:-1]
    (t0, t1, vic, pc, bud, nxt, prv, tgt, coh, ready, busy, opst,
     done, lat, latn, reacq, npass) = state[:17]

    for ref, val in ((s_t0, t0), (s_t1, t1), (s_vic, vic), (s_pc, pc),
                     (s_bud, bud), (s_nxt, nxt), (s_prev, prv), (s_tgt, tgt),
                     (s_coh, coh)):
        ref[...] = val
    for crefs, val in ((ready_refs, ready), (busy_refs, busy),
                       (opst_refs, opst)):
        C.write(crefs, val)
    done_ref[...] = done
    C.write(lat_refs, lat)
    latn_ref[...] = latn[:, None]
    C.write(tend_refs, C.col(C.reduce_max(ready)))
    reacq_ref[...] = reacq[:, None]
    npass_ref[...] = npass[:, None]
    if R > 0:
        (rstat, curreq, arrptr, qlen, wq, soj) = state[17:]
        rstat_ref[...] = rstat
        s_curreq[...] = curreq
        s_arrptr[...] = arrptr[:, None]
        s_qlen[...] = qlen[:, None]
        C.write(wq_refs, wq)
        C.write(soj_refs, soj)

"""Full SSD forward assembled from the intra-chunk kernel + jnp glue."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.ssd_scan.kernel import ssd_intra_chunk


def ssd_forward(xh, dt, a, b, c, *, chunk: int = 128, hb: int = 8,
                interpret: bool = False, use_kernel: bool = True):
    """SSD with the Pallas intra-chunk kernel. Same contract as
    ref.ssd_sequential. xh: (B,S,H,P); dt: (B,S,H); a: (H,); b,c: (B,S,N)."""
    B, S, H, P = xh.shape
    N = b.shape[-1]
    nc = S // chunk
    assert S % chunk == 0
    dtf = dt.astype(jnp.float32)
    dA = (dtf * a).reshape(B, nc, chunk, H)
    xd = (xh.astype(jnp.float32) * dtf[..., None]).reshape(
        B, nc, chunk, H, P)
    bc = b.reshape(B, nc, chunk, N)
    cc = c.reshape(B, nc, chunk, N)

    if use_kernel:
        y_d, states, chunk_decay = ssd_intra_chunk(
            xd, dA, bc, cc, hb=hb, interpret=interpret)
    else:  # jnp fallback with identical per-chunk math
        from repro.kernels.ssd_scan.ref import ssd_chunk_ref
        f = jax.vmap(jax.vmap(ssd_chunk_ref))
        y_d, states, chunk_decay = f(xd, dA, bc, cc)

    # inter-chunk recurrence (tiny): h_{i+1} = decay_i * h_i + states_i
    def scan_body(h, xs):
        st, dec = xs
        return h * dec[:, :, None, None] + st, h
    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    h_last, h_prevs = lax.scan(
        scan_body, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)               # (B,nc,H,P,N)

    dA_cs = jnp.cumsum(dA, axis=2)                      # (B,nc,L,H)
    y_o = jnp.einsum("bcln,bchpn,bclh->bclhp", cc, h_prevs,
                     jnp.exp(dA_cs))
    y = (y_d + y_o).reshape(B, S, H, P)
    return y, h_last

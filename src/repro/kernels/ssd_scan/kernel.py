"""Pallas TPU kernel for the SSD (state-space duality) intra-chunk block.

The Mamba2 SSD computation splits into (a) an intra-chunk quadratic part —
attention-like, compute-dense, perfect for the MXU — and (b) a tiny
inter-chunk recurrence over nc chunk states (left in jnp; it is O(nc·H·P·N)
and bandwidth-trivial). This kernel computes (a): per (batch, chunk, head
tile), the masked-decay local attention and the chunk's terminal state.

Grid: (B, nc, H//hb). VMEM per instance with L=128, hb=8, P=64, N=128:
x (L,hb,P) 256KB + decay (hb,L,L) 512KB + outputs — comfortably < 16MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(xd_ref, dA_ref, b_ref, c_ref, y_ref, st_ref, cd_ref, *,
                L: int, hb: int):
    xd = xd_ref[0, 0].astype(jnp.float32)        # (L, hb, P)
    dA = dA_ref[0, 0].astype(jnp.float32)        # (L, hb)
    b = b_ref[0, 0].astype(jnp.float32)          # (L, N)
    c = c_ref[0, 0].astype(jnp.float32)          # (L, N)

    cs = jnp.cumsum(dA, axis=0)                  # (L, hb)
    # pairwise decay (hb, L, L), lower-triangular
    diff = cs.T[:, :, None] - cs.T[:, None, :]
    li = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    mi = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.where((mi <= li)[None], jnp.exp(diff), 0.0)

    att = c @ b.T                                # (L, L)
    w = att[None] * decay                        # (hb, L, L)
    # y[l,h,p] = sum_m w[h,l,m] * xd[m,h,p]
    y = jax.lax.dot_general(
        w, jnp.moveaxis(xd, 1, 0),
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)      # (hb, L, P)
    y_ref[0, 0] = jnp.moveaxis(y, 0, 1).astype(y_ref.dtype)

    dstates = jnp.exp(cs[-1:, :] - cs)           # (L, hb)
    # states[h,p,n] = sum_l b[l,n] * dstates[l,h] * xd[l,h,p]
    xw = xd * dstates[:, :, None]                # (L, hb, P)
    st = jax.lax.dot_general(
        jnp.moveaxis(xw, 1, 0), b,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)      # (hb, P, N)
    st_ref[0, 0] = st.astype(st_ref.dtype)
    cd_ref[0, 0] = jnp.exp(cs[-1]).astype(cd_ref.dtype)


@functools.partial(jax.jit, static_argnames=("hb", "interpret"))
def ssd_intra_chunk(xd, dA, b, c, *, hb: int = 8, interpret: bool = False):
    """xd: (B,nc,L,H,P) dt-scaled inputs; dA: (B,nc,L,H); b,c: (B,nc,L,N).
    Returns y_diag (B,nc,L,H,P) f32, states (B,nc,H,P,N) f32,
    chunk_decay (B,nc,H) f32."""
    B, nc, L, H, P = xd.shape
    N = b.shape[-1]
    hb = min(hb, H)
    assert H % hb == 0
    grid = (B, nc, H // hb)
    kern = functools.partial(_ssd_kernel, L=L, hb=hb)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, L, hb, P),
                         lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, L, hb), lambda bi, ci, hi: (bi, ci, 0, hi)),
            pl.BlockSpec((1, 1, L, N), lambda bi, ci, hi: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, L, N), lambda bi, ci, hi: (bi, ci, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, hb, P),
                         lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, hb, P, N),
                         lambda bi, ci, hi: (bi, ci, hi, 0, 0)),
            pl.BlockSpec((1, 1, hb), lambda bi, ci, hi: (bi, ci, hi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc, L, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, nc, H, P, N), jnp.float32),
            jax.ShapeDtypeStruct((B, nc, H), jnp.float32),
        ],
        interpret=interpret,
    )(xd, dA, b, c)

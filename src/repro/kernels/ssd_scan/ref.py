"""Oracles for the SSD (Mamba2) kernel.

``ssd_sequential`` is the exact O(S) recurrence — the strongest reference:
    h_t = exp(dt_t * a) * h_{t-1} + dt_t * x_t ⊗ b_t
    y_t = c_t · h_t
Both the chunked jnp implementation (models.layers._ssd_chunked) and the
Pallas intra-chunk kernel are validated against it.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def ssd_sequential(xh, dt, a, b, c, h0=None):
    """xh: (B,S,H,P); dt: (B,S,H); a: (H,)<0; b,c: (B,S,N).
    Returns y: (B,S,H,P) f32, h_final: (B,H,P,N) f32."""
    B, S, H, P = xh.shape
    N = b.shape[-1]
    h = jnp.zeros((B, H, P, N), jnp.float32) if h0 is None else h0

    def step(h, t):
        dtt = dt[:, t].astype(jnp.float32)               # (B,H)
        dec = jnp.exp(dtt * a)                           # (B,H)
        upd = jnp.einsum("bhp,bn->bhpn",
                         xh[:, t].astype(jnp.float32) * dtt[..., None],
                         b[:, t].astype(jnp.float32))
        h = h * dec[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", c[:, t].astype(jnp.float32), h)
        return h, y

    h, ys = lax.scan(step, h, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1), h                     # (B,S,H,P)


def ssd_chunk_ref(xd, dA, b, c):
    """Intra-chunk reference for the kernel: one chunk, already dt-scaled.
    xd: (L,H,P); dA: (L,H); b,c: (L,N). Returns y_diag (L,H,P),
    states (H,P,N), chunk_decay (H,)."""
    L = xd.shape[0]
    cs = jnp.cumsum(dA, axis=0)                          # (L,H)
    diff = cs[:, None, :] - cs[None, :, :]               # (L,L,H)
    mask = jnp.tril(jnp.ones((L, L), bool))[:, :, None]
    decay = jnp.where(mask, jnp.exp(diff), 0.0)
    att = jnp.einsum("ln,mn->lm", c, b)                  # (L,L)
    y = jnp.einsum("lm,lmh,mhp->lhp", att, decay, xd)
    dstates = jnp.exp(cs[-1:, :] - cs)                   # (L,H)
    states = jnp.einsum("ln,lh,lhp->hpn", b, dstates, xd)
    return y, states, jnp.exp(cs[-1])

"""Pallas TPU kernel: batched ALock lock-table transition.

The Monte-Carlo fairness/throughput sweeps (benchmarks/fig4) evaluate the
ALock over thousands of independent single-lock tables × long schedules.
The hot loop is "apply thread-step `sched[i]` to every table" — embarrassing
table-parallelism with tiny per-table state, i.e. a VPU (vector unit) job:
grid tiles tables into VMEM-resident blocks of `tile` rows and applies the
whole `steps`-long schedule in-register, amortizing HBM traffic to one
read + one write of the state per call instead of per step.

Semantics are identical to ``repro.core.machine.alock_step`` (the kernel is
tested against ref.py, which is tested against the Python machine).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core import machine as mc


def _tick_kernel(sched_ref, coh_ref, tails_ref, vic_ref, pc_ref, bud_ref,
                 nxt_ref, prev_ref, o_tails, o_vic, o_pc, o_bud, o_nxt,
                 o_prev, *, T: int, steps: int, b_local: int, b_remote: int):
    tails = tails_ref[...].astype(jnp.int32)      # (tile, 2)
    vic = vic_ref[...].astype(jnp.int32)          # (tile, 1)
    pc = pc_ref[...].astype(jnp.int32)            # (tile, T)
    bud = bud_ref[...].astype(jnp.int32)
    nxt = nxt_ref[...].astype(jnp.int32)
    prev = prev_ref[...].astype(jnp.int32)
    sched = sched_ref[...].astype(jnp.int32)      # (tile, steps)
    coh = coh_ref[...].astype(jnp.int32)          # (tile, T)
    tile = pc.shape[0]
    rows = jnp.arange(tile)
    tids = jnp.arange(T)[None, :]                 # (1, T)

    def sel_t(arr, tid):
        """arr (tile,T) gathered at per-row tid -> (tile,)"""
        return jnp.sum(jnp.where(tids == tid[:, None], arr, 0), axis=1)

    def step(i, carry):
        tails, vic, pc, bud, nxt, prev = carry
        tid = sched[:, i]                          # (tile,)
        oh = tids == tid[:, None]                  # (tile, T)
        c = sel_t(coh, tid)                        # (tile,)
        me = tid + 1
        p = sel_t(pc, tid)
        B = jnp.where(c == 0, b_local, b_remote)
        tail_c = jnp.where(c == 0, tails[:, 0], tails[:, 1])
        tail_o = jnp.where(c == 0, tails[:, 1], tails[:, 0])
        v = vic[:, 0]

        is_ncs = p == mc.NCS
        bud = jnp.where((is_ncs[:, None]) & oh, -1, bud)
        nxt = jnp.where((is_ncs[:, None]) & oh, 0, nxt)

        is_swap = p == mc.SWAP
        empty = tail_c == 0
        new_tail_c = jnp.where(is_swap, me, tail_c)
        prev = jnp.where(is_swap[:, None] & oh, tail_c[:, None], prev)
        bud = jnp.where((is_swap & empty)[:, None] & oh, B[:, None], bud)

        is_wn = p == mc.WRITE_NEXT
        pred = sel_t(prev, tid) - 1
        oh_pred = tids == pred[:, None]
        nxt = jnp.where(is_wn[:, None] & oh_pred, me[:, None], nxt)

        is_sb = p == mc.SPIN_BUDGET
        b = sel_t(bud, tid)

        is_sv = (p == mc.SET_VICTIM) | (p == mc.SET_VICTIM_R)
        v = jnp.where(is_sv, c, v)

        is_pw = (p == mc.PET_WAIT) | (p == mc.PET_WAIT_R)
        can = (tail_o == 0) | (v != c)
        is_pwr = p == mc.PET_WAIT_R
        bud = jnp.where((is_pwr & can)[:, None] & oh, B[:, None], bud)

        is_rc = p == mc.REL_CAS
        solo = new_tail_c == me
        new_tail_c = jnp.where(is_rc & solo, 0, new_tail_c)

        is_sn = p == mc.SPIN_NEXT
        has_succ = sel_t(nxt, tid) != 0

        is_pass = p == mc.PASS
        succ = sel_t(nxt, tid) - 1
        oh_succ = tids == succ[:, None]
        bud = jnp.where(is_pass[:, None] & oh_succ, (b - 1)[:, None], bud)

        new_pc = jnp.select(
            [is_ncs, is_swap, is_wn, is_sb, p == mc.SET_VICTIM,
             p == mc.SET_VICTIM_R, is_pw, p == mc.CS, is_rc, is_sn,
             is_pass],
            [jnp.full_like(p, mc.SWAP),
             jnp.where(empty, mc.SET_VICTIM, mc.WRITE_NEXT),
             jnp.full_like(p, mc.SPIN_BUDGET),
             jnp.where(b == -1, mc.SPIN_BUDGET,
                       jnp.where(b == 0, mc.SET_VICTIM_R, mc.CS)),
             jnp.full_like(p, mc.PET_WAIT),
             jnp.full_like(p, mc.PET_WAIT_R),
             jnp.where(can, mc.CS,
                       jnp.where(is_pwr, mc.PET_WAIT_R, mc.PET_WAIT)),
             jnp.full_like(p, mc.REL_CAS),
             jnp.where(solo, mc.NCS, mc.SPIN_NEXT),
             jnp.where(has_succ, mc.PASS, mc.SPIN_NEXT),
             jnp.full_like(p, mc.NCS)],
            p)
        pc = jnp.where(oh, new_pc[:, None], pc)
        tails = jnp.where((c == 0)[:, None],
                          jnp.stack([new_tail_c, tails[:, 1]], axis=1),
                          jnp.stack([tails[:, 0], new_tail_c], axis=1))
        vic = v[:, None]
        return tails, vic, pc, bud, nxt, prev

    tails, vic, pc, bud, nxt, prev = lax.fori_loop(
        0, steps, step, (tails, vic, pc, bud, nxt, prev))
    o_tails[...] = tails
    o_vic[...] = vic
    o_pc[...] = pc
    o_bud[...] = bud
    o_nxt[...] = nxt
    o_prev[...] = prev


@functools.partial(jax.jit,
                   static_argnames=("b_init", "tile", "interpret"))
def alock_tick(tails, victim, pc, budget, nxt, prev, sched, cohorts, *,
               b_init=(5, 20), tile: int = 128, interpret: bool = False):
    """Apply (Tab, steps) schedules to Tab independent single-lock tables.

    tails (Tab,2), victim (Tab,1), pc/budget/nxt/prev (Tab,T),
    sched (Tab,steps), cohorts (Tab,T) — all int32.

    Tab need not be a multiple of `tile`: the batch is zero-padded to the
    next tile boundary (pad rows are fresh all-NCS tables stepped by thread
    0 — valid but ignored) and the outputs are sliced back to Tab rows.
    """
    Tab, T = pc.shape
    steps = sched.shape[1]
    tile = min(tile, Tab)
    pad = -Tab % tile
    if pad:
        def zpad(a):
            return jnp.pad(a, ((0, pad), (0, 0)))
        sched, cohorts, tails, victim, pc, budget, nxt, prev = map(
            zpad, (sched, cohorts, tails, victim, pc, budget, nxt, prev))
    ptab = Tab + pad
    grid = (ptab // tile,)
    kern = functools.partial(_tick_kernel, T=T, steps=steps,
                             b_local=int(b_init[0]), b_remote=int(b_init[1]))

    def row_spec(w):
        return pl.BlockSpec((tile, w), lambda i: (i, 0))

    shapes = [(ptab, 2), (ptab, 1), (ptab, T), (ptab, T), (ptab, T),
              (ptab, T)]
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[row_spec(steps), row_spec(T)] + [
            row_spec(s[1]) for s in shapes],
        out_specs=[row_spec(s[1]) for s in shapes],
        out_shape=[jax.ShapeDtypeStruct(s, jnp.int32) for s in shapes],
        interpret=interpret,
    )(sched, cohorts, tails, victim, pc, budget, nxt, prev)
    if pad:
        out = [o[:Tab] for o in out]
    return out

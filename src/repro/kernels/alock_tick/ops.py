"""Monte-Carlo driver over the batched lock-table kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import machine as mc
from repro.kernels.alock_tick.kernel import alock_tick
from repro.kernels.alock_tick.ref import alock_tick_ref


def fresh_tables(n_tables: int, n_threads: int):
    z = lambda: jnp.zeros((n_tables, n_threads), jnp.int32)
    return (jnp.zeros((n_tables, 2), jnp.int32),
            jnp.zeros((n_tables, 1), jnp.int32),
            jnp.full((n_tables, n_threads), mc.NCS, jnp.int32),
            jnp.full((n_tables, n_threads), -1, jnp.int32), z(), z())


def monte_carlo_cs_entries(n_tables: int, n_threads: int, steps: int,
                           cohorts, b_init=(5, 20), seed: int = 0,
                           use_kernel: bool = True, interpret: bool = True):
    """Run random schedules over many tables; count CS entries per cohort
    (the fairness statistic behind Fig. 4's budget study)."""
    key = jax.random.key(seed)
    sched = jax.random.randint(key, (n_tables, steps), 0, n_threads,
                               dtype=jnp.int32)
    coh = jnp.broadcast_to(jnp.asarray(cohorts, jnp.int32),
                           (n_tables, n_threads))
    tails, vic, pc, bud, nxt, prev = fresh_tables(n_tables, n_threads)
    if use_kernel:
        out = alock_tick(tails, vic, pc, bud, nxt, prev, sched, coh,
                         b_init=tuple(b_init), tile=min(128, n_tables),
                         interpret=interpret)
    else:
        out = alock_tick_ref(tails, vic[:, 0], pc, bud, nxt, prev, sched,
                             jnp.asarray(cohorts, jnp.int32),
                             jnp.asarray(b_init, jnp.int32))
    pc_fin = out[2]
    in_cs = (pc_fin == mc.CS)
    return {"in_cs_frac": float(in_cs.mean()),
            "final_pc_histogram": jnp.bincount(pc_fin.reshape(-1),
                                               length=14)}

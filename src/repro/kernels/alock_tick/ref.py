"""Pure-jnp oracle: branchless ALock transition, vectorized over a batch of
independent single-lock tables.

Semantics mirror ``repro.core.machine.alock_step`` exactly (validated in
tests against the Python machine). State per table, T threads:
  tails (2,), victim (), pc (T,), budget (T,), nxt (T,), prev (T,)
A schedule entry picks which thread steps; one call applies `steps`
schedule entries sequentially to every table in the batch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import machine as mc


def alock_transition(tails, victim, pc, budget, nxt, prev, tid, cohorts,
                     b_init):
    """One branchless ALock step for ONE table. All args jnp scalars/1-D.
    tid: scalar thread index. Returns updated (tails, victim, pc, budget,
    nxt, prev)."""
    T = pc.shape[0]
    c = cohorts[tid]
    me = tid + 1
    p = pc[tid]
    B = b_init[c]
    oh = (jnp.arange(T) == tid)

    # --- NCS: reset descriptor
    is_ncs = p == mc.NCS
    budget = jnp.where(is_ncs & oh, -1, budget)
    nxt = jnp.where(is_ncs & oh, 0, nxt)

    # --- SWAP
    is_swap = p == mc.SWAP
    prev_val = tails[c]
    empty = prev_val == 0
    tails = jnp.where(is_swap, tails.at[c].set(me), tails)
    prev = jnp.where(is_swap & oh, prev_val, prev)
    budget = jnp.where(is_swap & empty & oh, B, budget)

    # --- WRITE_NEXT
    is_wn = p == mc.WRITE_NEXT
    pred = prev[tid] - 1
    oh_pred = (jnp.arange(T) == pred)
    nxt = jnp.where(is_wn & oh_pred, me, nxt)

    # --- SPIN_BUDGET
    is_sb = p == mc.SPIN_BUDGET
    b = budget[tid]

    # --- SET_VICTIM / SET_VICTIM_R
    is_sv = (p == mc.SET_VICTIM) | (p == mc.SET_VICTIM_R)
    victim = jnp.where(is_sv, c, victim)

    # --- PET_WAIT / PET_WAIT_R
    is_pw = (p == mc.PET_WAIT) | (p == mc.PET_WAIT_R)
    can = (tails[1 - c] == 0) | (victim != c)
    is_pwr = p == mc.PET_WAIT_R
    budget = jnp.where(is_pwr & can & oh, B, budget)

    # --- REL_CAS
    is_rc = p == mc.REL_CAS
    solo = tails[c] == me
    tails = jnp.where(is_rc & solo, tails.at[c].set(0), tails)

    # --- SPIN_NEXT
    is_sn = p == mc.SPIN_NEXT
    has_succ = nxt[tid] != 0

    # --- PASS
    is_pass = p == mc.PASS
    succ = nxt[tid] - 1
    oh_succ = (jnp.arange(T) == succ)
    budget = jnp.where(is_pass & oh_succ, budget[tid] - 1, budget)

    # --- next pc
    new_pc = jnp.select(
        [is_ncs, is_swap, is_wn, is_sb, p == mc.SET_VICTIM,
         p == mc.SET_VICTIM_R, is_pw, p == mc.CS, is_rc, is_sn, is_pass],
        [jnp.int32(mc.SWAP),
         jnp.where(empty, mc.SET_VICTIM, mc.WRITE_NEXT).astype(jnp.int32),
         jnp.int32(mc.SPIN_BUDGET),
         jnp.where(b == -1, mc.SPIN_BUDGET,
                   jnp.where(b == 0, mc.SET_VICTIM_R, mc.CS)).astype(jnp.int32),
         jnp.int32(mc.PET_WAIT), jnp.int32(mc.PET_WAIT_R),
         jnp.where(can, mc.CS,
                   jnp.where(is_pwr, mc.PET_WAIT_R, mc.PET_WAIT)).astype(jnp.int32),
         jnp.int32(mc.REL_CAS),
         jnp.where(solo, mc.NCS, mc.SPIN_NEXT).astype(jnp.int32),
         jnp.where(has_succ, mc.PASS, mc.SPIN_NEXT).astype(jnp.int32),
         jnp.int32(mc.NCS)],
        p)
    pc = jnp.where(oh, new_pc, pc)
    return tails, victim, pc, budget, nxt, prev


def alock_tick_ref(tails, victim, pc, budget, nxt, prev, sched, cohorts,
                   b_init):
    """Apply a (Tab, steps) schedule to a batch of tables — jnp oracle."""
    def one(tails, victim, pc, budget, nxt, prev, sched_row):
        def body(carry, tid):
            return alock_transition(*carry, tid, cohorts, b_init), None
        (tails, victim, pc, budget, nxt, prev), _ = lax.scan(
            body, (tails, victim, pc, budget, nxt, prev), sched_row)
        return tails, victim, pc, budget, nxt, prev
    return jax.vmap(one)(tails, victim, pc, budget, nxt, prev, sched)

"""AdamW + cosine schedule + global-norm clipping, pure JAX.

Optimizer state (m, v) is kept in f32 with the *same logical axes* as the
parameters, so FSDP sharding of the optimizer state falls out of the same
rule table (ZeRO-style: 8 bytes/param spread over the data axis).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec, is_spec

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    clip_norm: float = 1.0


def opt_state_specs(param_specs):
    """Specs for (m, v): same shapes/axes as params, f32, zero-init."""
    def f32spec(s: ParamSpec) -> ParamSpec:
        return ParamSpec(s.shape, s.axes, "zeros", None, F32)
    mk = lambda: jax.tree_util.tree_map(f32spec, param_specs, is_leaf=is_spec)
    return {"m": mk(), "v": mk()}


def init_opt_state(params):
    z = lambda: jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, F32), params)
    return {"m": z(), "v": z()}


def schedule(cfg: OptConfig, step):
    step = step.astype(F32)
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps) /
                 max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac * cfg.lr + (1 - cfg.min_lr_frac) * cfg.lr * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree):
    sq = sum(jnp.sum(jnp.square(x.astype(F32)))
             for x in jax.tree_util.tree_leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(cfg: OptConfig, params, grads, state, step):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    t = step.astype(F32) + 1.0
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(F32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        step_ = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(F32)
        newp = (p.astype(F32) - lr * step_).astype(p.dtype)
        return newp, m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}

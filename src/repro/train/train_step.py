"""Train / prefill / decode step builders.

``make_train_step`` is the synchronous baseline (gradient mean over the full
batch — GSPMD inserts the hierarchical all-reduce). The budgeted cohort
variant (the paper's remote-budget idea applied to cross-pod sync) lives in
``repro.parallel.collectives``.
"""
from __future__ import annotations


import jax

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.train.optimizer import OptConfig, adamw_update


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig):
    def train_step(params, opt_state, batch, step):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch), has_aux=True)(params)
        params, opt_state, opt_metrics = adamw_update(
            opt_cfg, params, grads, opt_state, step)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics
    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return M.prefill(cfg, params, batch)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, tokens, pos):
        return M.decode_step(cfg, params, cache, tokens, pos)
    return decode_step

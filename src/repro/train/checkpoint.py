"""Checkpointing: sharded save/restore with ALock-leased writers + async
snapshots.

Layout: <dir>/step_<N>/arrays.npz + manifest.json (written LAST — the
commit marker; restore only considers steps with a manifest). On a cluster,
each data-parallel replica group elects one writer through a LeaseManager
lease, so a partitioned/slow node can never double-write, and a crashed
writer's lease expires so a peer takes over — fault tolerance comes from
the paper's lock, not from hoping rsync wins races.
"""
from __future__ import annotations

import json
import os
import threading
import time

import jax
import numpy as np

from repro.coord.service import LeaseManager


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, state: dict, *,
                    lease_mgr: LeaseManager | None = None,
                    node_id: int = 0) -> bool:
    """Returns True if this caller performed the write (lease winner)."""
    lease = None
    if lease_mgr is not None:
        lease = lease_mgr.acquire(node_id, f"ckpt:{step}")
        if lease is None:
            return False
    try:
        d = os.path.join(ckpt_dir, f"step_{step:08d}")
        os.makedirs(d, exist_ok=True)
        leaves, treedef = _flatten(state)

        def to_np(x):
            a = np.asarray(jax.device_get(x))
            if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
                a = a.astype(np.float32)   # lossless upcast; restore recasts
            return a

        arrs = {f"a{i}": to_np(x) for i, x in enumerate(leaves)}
        np.savez(os.path.join(d, "arrays.npz"), **arrs)
        manifest = {"step": step, "n_leaves": len(leaves),
                    "treedef": str(treedef), "time": time.time()}
        tmp = os.path.join(d, "manifest.json.tmp")
        with open(tmp, "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, os.path.join(d, "manifest.json"))
        return True
    finally:
        if lease is not None:
            lease_mgr.release(lease)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, state_like, step: int | None = None):
    """Returns (step, state) or (None, None)."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        return None, None
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(d, "arrays.npz"))
    leaves, treedef = _flatten(state_like)
    new_leaves = []
    for i, old in enumerate(leaves):
        arr = data[f"a{i}"]
        assert arr.shape == old.shape, (i, arr.shape, old.shape)
        new_leaves.append(jax.numpy.asarray(arr, dtype=old.dtype))
    return step, jax.tree_util.tree_unflatten(treedef, new_leaves)


class AsyncCheckpointer:
    """Snapshot on the caller thread (cheap device_get of a donated copy),
    write on a background thread — training never blocks on disk."""

    def __init__(self, ckpt_dir: str, lease_mgr: LeaseManager | None = None,
                 node_id: int = 0):
        self.dir = ckpt_dir
        self.lease_mgr = lease_mgr
        self.node_id = node_id
        self._thread: threading.Thread | None = None
        self.last_result: bool | None = None

    def save(self, step: int, state: dict):
        self.wait()
        snap = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)),
                                      state)

        def _write():
            self.last_result = save_checkpoint(
                self.dir, step, snap, lease_mgr=self.lease_mgr,
                node_id=self.node_id)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

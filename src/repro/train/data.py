"""Deterministic synthetic LM data pipeline.

Sequences follow a noisy affine-mod bigram process
    tok[t+1] = (3 * tok[t] + 7 + e_t) mod V,  e_t ~ U{0, 1, 2}
so a model can learn it (cross-entropy floor = ln 3 ≈ 1.10 nats) and a
training run has a verifiable convergence target. Batches are addressable
by (seed, shard, step): restart-after-crash resumes mid-stream exactly, and
shard ownership integrates with coord.Membership for elastic scaling /
straggler work-stealing.
"""
from __future__ import annotations

import numpy as np


class SyntheticLM:
    def __init__(self, vocab: int, seq_len: int, batch_per_shard: int,
                 seed: int = 0, modulus: int | None = None):
        self.vocab = vocab
        # tokens live in [0, modulus): the bigram table then has rank
        # <= modulus, so small-d_model smoke models can reach the floor
        self.modulus = modulus or min(32, vocab)
        self.seq = seq_len
        self.bps = batch_per_shard
        self.seed = seed

    def batch(self, shard: int, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, shard, step]))
        b, m = self.bps, self.modulus
        toks = np.empty((b, self.seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, m, b)
        noise = rng.integers(0, 3, (b, self.seq))
        for t in range(self.seq):
            toks[:, t + 1] = (3 * toks[:, t] + 7 + noise[:, t]) % m
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def entropy_floor(self) -> float:
        return float(np.log(3.0))


def global_batch(ds: SyntheticLM, shards: list[int], step: int) -> dict:
    parts = [ds.batch(s, step) for s in shards]
    return {k: np.concatenate([p[k] for p in parts], axis=0)
            for k in parts[0]}

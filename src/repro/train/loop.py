"""Fault-tolerant training loop.

Single-controller loop wiring together: synthetic data shards (ownership via
coord.Membership), jitted train step, async lease-guarded checkpoints,
restart-from-latest, failure injection, and straggler shard-stealing. The
distributed aspects run against the in-process coordination plane — the same
code paths a multi-host deployment drives through jax.distributed's KV store.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.coord.service import CoordService, LeaseManager, Membership
from repro.models import model as M
from repro.models.params import init_tree
from repro.train import checkpoint as ckpt
from repro.train.data import SyntheticLM, global_batch
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step


@dataclasses.dataclass
class LoopConfig:
    steps: int = 200
    ckpt_every: int = 50
    ckpt_dir: str = "artifacts/ckpt"
    batch_per_shard: int = 2
    n_shards: int = 4
    seq_len: int = 128
    seed: int = 0
    fail_at_step: int | None = None     # failure injection (tests/examples)
    log_every: int = 20


class Trainer:
    def __init__(self, cfg: ModelConfig, opt: OptConfig, loop: LoopConfig,
                 svc: CoordService | None = None, node_id: int = 0):
        self.cfg, self.opt, self.loop = cfg, opt, loop
        self.node_id = node_id
        self.svc = svc or CoordService(n_nodes=1)
        self.leases = LeaseManager(self.svc, ttl_s=10.0)
        self.members = Membership(self.svc, heartbeat_ttl=5.0)
        self.ds = SyntheticLM(cfg.vocab, loop.seq_len, loop.batch_per_shard,
                              loop.seed)
        self.step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))
        self.checkpointer = ckpt.AsyncCheckpointer(
            loop.ckpt_dir, lease_mgr=self.leases, node_id=node_id)
        self.history: list[dict] = []

    def init_state(self):
        params = init_tree(M.model_specs(self.cfg),
                           jax.random.key(self.loop.seed))
        return {"params": params, "opt": init_opt_state(params),
                "step": jnp.zeros((), jnp.int32)}

    def run(self, state=None, resume: bool = True) -> dict:
        loop = self.loop
        self.members.join(self.node_id)
        shards = self.members.assign_shards(self.node_id, loop.n_shards)
        if state is None:
            state = self.init_state()
            if resume:
                got_step, got = ckpt.restore_checkpoint(loop.ckpt_dir, state)
                if got is not None:
                    state = got
        start = int(state["step"])
        for step in range(start, loop.steps):
            self.members.heartbeat(self.node_id)
            if loop.fail_at_step is not None and step == loop.fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            batch_np = global_batch(self.ds, shards, step)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            params, opt, metrics = self.step_fn(
                state["params"], state["opt"], batch,
                jnp.asarray(step, jnp.int32))
            state = {"params": params, "opt": opt,
                     "step": jnp.asarray(step + 1, jnp.int32)}
            if step % loop.log_every == 0 or step == loop.steps - 1:
                rec = {"step": step,
                       "loss": float(metrics["loss"]),
                       "grad_norm": float(metrics["grad_norm"])}
                self.history.append(rec)
            if (step + 1) % loop.ckpt_every == 0:
                self.checkpointer.save(step + 1, state)
        self.checkpointer.wait()
        return state

"""Batched decode engine: prefill once, then greedy/temperature decode with
a ring KV cache, per-request stop lengths, and step-level batching."""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0   # 0 = greedy
    seed: int = 0


class Engine:
    def __init__(self, cfg: ModelConfig, params, serve: ServeConfig =
                 ServeConfig()):
        self.cfg, self.params, self.serve = cfg, params, serve
        self._prefill = jax.jit(functools.partial(
            M.prefill, cfg, cache_len=None), static_argnames=("cache_len",))
        self._decode = jax.jit(
            lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos),
            donate_argnums=(1,))

    def generate(self, batch: dict) -> np.ndarray:
        """batch: {tokens (B,S), [vision_embeds/enc_embeds]}. Returns
        (B, max_new_tokens) generated ids."""
        cfg, sv = self.cfg, self.serve
        B, S = batch["tokens"].shape
        logits, cache = self._prefill(self.params, batch,
                                      cache_len=S + sv.max_new_tokens)
        key = jax.random.key(sv.seed)
        outs = []
        tok = self._sample(logits, key, 0)
        for i in range(sv.max_new_tokens):
            outs.append(np.asarray(tok))
            logits, cache = self._decode(self.params, cache,
                                         tok[:, None],
                                         jnp.asarray(S + i, jnp.int32))
            tok = self._sample(logits, key, i + 1)
        return np.stack(outs, axis=1)

    def _sample(self, logits, key, i):
        if self.serve.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        k = jax.random.fold_in(key, i)
        return jax.random.categorical(
            k, logits / self.serve.temperature, axis=-1).astype(jnp.int32)

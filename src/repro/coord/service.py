"""Host-side coordination plane built on the ALock lock table.

One `CoordService` emulates the control plane of a multi-pod training job:
named locks (hashed onto the distributed table), writer leases, membership.
On a real cluster each node talks to the table over its own transport; here
nodes are threads, and the asymmetric lock keeps local participants on
shared-memory ops — the paper's point, applied to the runtime.
"""
from __future__ import annotations

import threading
import time
import zlib
from dataclasses import dataclass

from repro.core.lock_table import LockTable


class CoordService:
    def __init__(self, n_nodes: int, locks_per_node: int = 64,
                 local_budget: int = 5, remote_budget: int = 20, net=None):
        self.table = LockTable(n_nodes, locks_per_node, local_budget,
                               remote_budget, net=net)
        self.n_nodes = n_nodes
        self._kv: dict = {}
        self._kv_lock = threading.Lock()

    def lock_id(self, name: str) -> int:
        return zlib.crc32(name.encode()) % len(self.table.cells)

    def critical(self, node_id: int, name: str):
        return self.table.critical(node_id, self.lock_id(name))

    # a tiny strongly-consistent KV (guarded by the table's locks)
    def put(self, node_id: int, key: str, value):
        with self.critical(node_id, "kv:" + key):
            with self._kv_lock:
                self._kv[key] = value

    def get(self, key: str):
        with self._kv_lock:
            return self._kv.get(key)

    def update(self, node_id: int, key: str, fn, default=None):
        with self.critical(node_id, "kv:" + key):
            with self._kv_lock:
                cur = self._kv.get(key, default)
                new = fn(cur)
                self._kv[key] = new
                return new


@dataclass
class Lease:
    name: str
    holder: int
    deadline: float
    epoch: int


class LeaseManager:
    """Writer leases (checkpointing, log ownership) with crash expiry.

    acquire() is mutual-exclusive via the ALock; expiry lets a restarted
    node steal a dead holder's lease after ttl.

    ``clock`` is any zero-arg callable returning seconds (default
    ``time.monotonic``). Injecting a manual clock makes lease-expiry-storm
    scenarios deterministic — ``coord/stress.py`` and the tests drive
    expiry by advancing the clock instead of sleeping.
    """

    def __init__(self, svc: CoordService, ttl_s: float = 5.0,
                 clock=time.monotonic):
        self.svc = svc
        self.ttl = ttl_s
        self._clock = clock

    def acquire(self, node_id: int, name: str, *, attempts: int = 1,
                deadline_s: float | None = None,
                backoff_base_s: float = 0.05, backoff_max_s: float = 1.0,
                rng=None, sleep=None) -> Lease | None:
        """Acquire (or steal an expired) lease; ``None`` when held live.

        ``attempts > 1`` turns one shot into a bounded retry loop with
        exponential backoff: attempt ``i`` failing sleeps
        ``min(base * 2**i, max)``, jittered into ``[0.5, 1.0)`` of itself
        when an ``rng`` (anything with ``.random()``) is injected — a
        seeded rng keeps the schedule deterministic while still
        de-synchronizing contending nodes. ``deadline_s`` bounds the
        *total* time budget measured on the injected ``clock``: no sleep
        ever overshoots it, and the loop stops retrying once it is spent.
        ``sleep`` defaults to ``ManualClock.advance`` when the clock is
        manual (tests/stress advance virtual time, no real waiting) and
        ``time.sleep`` otherwise.
        """
        if attempts < 1:
            raise ValueError(f"attempts must be >= 1, got {attempts}")
        if sleep is None:
            sleep = getattr(self._clock, "advance", time.sleep)
        start = self._clock()
        for i in range(attempts):
            lease = self._try_acquire(node_id, name)
            if lease is not None:
                return lease
            if i + 1 >= attempts:
                break
            d = min(backoff_base_s * (2.0 ** i), backoff_max_s)
            if rng is not None:
                d *= 0.5 + 0.5 * rng.random()
            if deadline_s is not None:
                remaining = deadline_s - (self._clock() - start)
                if remaining <= 0.0:
                    break
                d = min(d, remaining)
            sleep(d)
        return None

    def _try_acquire(self, node_id: int, name: str) -> Lease | None:
        with self.svc.critical(node_id, "lease:" + name):
            cur: Lease | None = self.svc.get("lease:" + name)
            now = self._clock()
            if cur is not None and cur.deadline > now and \
                    cur.holder != node_id:
                return None
            epoch = (cur.epoch + 1) if cur is not None else 0
            lease = Lease(name, node_id, now + self.ttl, epoch)
            with self.svc._kv_lock:
                self.svc._kv["lease:" + name] = lease
            return lease

    def renew(self, lease: Lease) -> bool:
        with self.svc.critical(lease.holder, "lease:" + lease.name):
            cur: Lease | None = self.svc.get("lease:" + lease.name)
            if cur is None or cur.epoch != lease.epoch:
                return False
            lease.deadline = self._clock() + self.ttl
            with self.svc._kv_lock:
                self.svc._kv["lease:" + lease.name] = lease
            return True

    def release(self, lease: Lease):
        with self.svc.critical(lease.holder, "lease:" + lease.name):
            cur: Lease | None = self.svc.get("lease:" + lease.name)
            if cur is not None and cur.epoch == lease.epoch:
                cur.deadline = 0.0


class Membership:
    """Elastic membership + heartbeat + straggler-aware shard ownership.

    ``clock`` mirrors :class:`LeaseManager`'s injectable clock so churn
    scenarios (node join/leave storms) run deterministically in tests.
    """

    def __init__(self, svc: CoordService, heartbeat_ttl: float = 2.0,
                 clock=time.monotonic):
        self.svc = svc
        self.ttl = heartbeat_ttl
        self._clock = clock

    def join(self, node_id: int):
        def upd(m):
            m = dict(m or {})
            m[node_id] = self._clock()
            return m
        self.svc.update(node_id, "members", upd, default={})

    def heartbeat(self, node_id: int):
        self.join(node_id)

    def alive(self) -> list[int]:
        m = self.svc.get("members") or {}
        now = self._clock()
        return sorted(n for n, t in m.items() if now - t < self.ttl)

    def leave(self, node_id: int):
        self.svc.update(node_id, "members",
                        lambda m: {k: v for k, v in (m or {}).items()
                                   if k != node_id}, default={})

    # ---- work shards (data pipeline ranges) ------------------------------
    def assign_shards(self, node_id: int, n_shards: int) -> list[int]:
        """Deterministic re-partition of shard ownership over live nodes —
        called after membership changes; lock-guarded so exactly one
        assignment wins per epoch."""
        with self.svc.critical(node_id, "shards"):
            live = self.alive()
            if not live:
                return []
            owner = {s: live[s % len(live)] for s in range(n_shards)}
            with self.svc._kv_lock:
                self.svc._kv["shards"] = owner
            return [s for s, n in owner.items() if n == node_id]

    def steal_from(self, node_id: int, dead_node: int) -> list[int]:
        """Straggler/failure mitigation: re-own a dead node's shards.

        Tolerates the "dead" node racing a late heartbeat: liveness is
        re-checked *inside* the shards critical section (the same lock
        :meth:`assign_shards` serializes on), and a target that
        heartbeated within the TTL aborts the steal — the caller keeps
        only what it already owns, and the revived node's shards stay
        put instead of being clobbered mid-recovery.
        """
        with self.svc.critical(node_id, "shards"):
            owner = dict(self.svc.get("shards") or {})
            if dead_node in self.alive():
                return [s for s, n in owner.items() if n == node_id]
            for s, n in owner.items():
                if n == dead_node:
                    owner[s] = node_id
            with self.svc._kv_lock:
                self.svc._kv["shards"] = owner
            return [s for s, n in owner.items() if n == node_id]

"""Coordination-plane stress scenarios driven by declarative Workload specs.

The simulator and the *real* (threaded) coordination plane share one
scenario language: a ``repro.workloads.Workload`` — per-thread locality,
Zipf-skewed lock choice, and phases (hot-key storms, node churn via
``down_nodes``) — here drives ``CoordService``'s lock table, lease manager
and membership instead of the event-loop engines.

Phases map onto the per-thread *operation* axis (op ``o`` of
``ops_per_thread`` lands in the phase covering fraction ``o / ops``).
At each phase boundary the runner advances an injected manual clock past
the lease TTL, so every phase opens with a lease-expiry storm: up nodes
race to (re)acquire per-node leases, and leases of downed nodes are stolen
— deterministically, because the clock never depends on wall time. Lock
traffic itself runs on real threads (actual concurrency), while the draw
streams are per-thread seeded, so op *counts and targets* are reproducible
even though interleavings are not.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field

import numpy as np

from repro.coord.service import CoordService, LeaseManager, Membership
from repro.workloads import Workload, lower


class ManualClock:
    """Injectable deterministic clock for LeaseManager/Membership."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += float(dt)
        return self.t


@dataclass
class StressReport:
    ops: int = 0
    local_ops: int = 0
    remote_ops: int = 0
    reacquires: int = 0
    lease_grants: int = 0
    lease_steals: int = 0          # grants that fenced off a prior epoch
    lease_retries: int = 0         # backoff sleeps the retry loop took
    phase_members: list = field(default_factory=list)  # alive() per phase
    per_node_ops: list = field(default_factory=list)


def run_coord_stress(w: Workload, ops_per_thread: int = 200,
                     lease_ttl: float = 5.0,
                     clock: ManualClock | None = None) -> StressReport:
    """Drive the threaded coordination plane through ``w``'s phase program.

    Returns a :class:`StressReport`; with the default :class:`ManualClock`
    the lease/membership half is fully deterministic and the lock-traffic
    half is deterministic in counts (per-thread seeded draw streams).
    """
    clock = clock or ManualClock()
    N, tpn, K = w.n_nodes, w.threads_per_node, w.n_locks
    kpn = K // N
    T = N * tpn
    # reuse the simulator's lowering so both planes interpret the spec
    # identically (locality rows, CDFs, phase edges over a 1k-op axis)
    lw = lower(w, n_events=1000)
    o = lw.operands
    P = o.n_phases
    svc = CoordService(N, locks_per_node=kpn,
                       local_budget=w.b_init[0], remote_budget=w.b_init[1])
    leases = LeaseManager(svc, ttl_s=lease_ttl, clock=clock)
    members = Membership(svc, heartbeat_ttl=lease_ttl, clock=clock)
    rep = StressReport(per_node_ops=[0] * N)
    ops_lock = threading.Lock()
    epochs: dict[str, int] = {}

    # phase per op index, hoisted out of the threaded hot loop
    frac_edge = o.edges.astype(np.float64) / 1000.0
    op_phase = (np.searchsorted(
        frac_edge, np.arange(ops_per_thread) / ops_per_thread,
        side="right") - 1).tolist()

    def node_up(p: int, node: int) -> bool:
        return bool(o.active[p, node * tpn])

    # two barriers per phase: the main thread opens the phase (clock
    # already advanced past the TTL), then runs the lease/membership storm
    # CONCURRENTLY with that phase's lock traffic — the coord plane is
    # stressed under live table contention, not in isolation
    enter = threading.Barrier(T + 1)
    leave = threading.Barrier(T + 1)

    def worker(tid: int):
        node = tid // tpn
        rng = np.random.default_rng(w.seed * 100_003 + tid)
        for p in range(P):
            enter.wait()
            for op in range(ops_per_thread):
                if op_phase[op] != p:
                    continue
                if not node_up(p, node):
                    continue               # node is down this phase
                if rng.random() < float(o.locality[p, tid]):
                    tgt = node
                else:
                    tgt = int((node + 1 + rng.integers(0, max(N - 1, 1)))
                              % N)
                off = int(np.searchsorted(o.zcdf[p], rng.random(),
                                          side="right"))
                lk = tgt * kpn + min(off, kpn - 1)
                with svc.table.critical(node, lk):
                    pass
                with ops_lock:
                    rep.per_node_ops[node] += 1
            leave.wait()

    ths = [threading.Thread(target=worker, args=(t,)) for t in range(T)]
    [t.start() for t in ths]
    for p in range(P):
        # lease-expiry storm at the phase boundary: everything outstanding
        # times out at once, up nodes re-acquire, dead nodes get stolen
        clock.advance(lease_ttl + 1.0)
        enter.wait()
        up = [n for n in range(N) if node_up(p, n)]
        for n in range(N):
            (members.join if n in up else members.leave)(n)
        for n in up:
            for victim in range(N):
                # bounded retry with deterministic jitter: the seeded rng
                # fixes the backoff schedule, the injected sleep advances
                # the manual clock (and counts the retries) — contended
                # names still resolve to one holder per storm
                def _sleep(d):
                    rep.lease_retries += 1
                    clock.advance(d)
                lease = leases.acquire(
                    n, f"shard:{victim}", attempts=2,
                    backoff_base_s=0.05, deadline_s=0.5,
                    rng=np.random.default_rng(
                        w.seed * 611_953 + p * 1009 + n * 31 + victim),
                    sleep=_sleep)
                if lease is None:
                    continue
                rep.lease_grants += 1
                prev = epochs.get(lease.name)
                if prev is not None and lease.epoch == prev + 1:
                    rep.lease_steals += 1
                epochs[lease.name] = lease.epoch
        rep.phase_members.append(members.alive())
        leave.wait()
    [t.join() for t in ths]
    st = svc.table.stats
    rep.ops = st.ops
    rep.local_ops = st.local_ops
    rep.remote_ops = st.remote_ops
    rep.reacquires = st.reacquires
    return rep

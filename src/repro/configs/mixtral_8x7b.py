"""mixtral-8x7b — 8 experts top-2 MoE with sliding-window attention (4096).
[arXiv:2401.04088; hf]  32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000.
"""
from repro.configs.base import LayerSpec, ModelConfig, register, uniform_groups

CFG = register(ModelConfig(
    name="mixtral-8x7b",
    d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=32000,
    groups=uniform_groups(
        32, LayerSpec(mixer="attn", ffn="moe", window=4096)),
    rope_theta=1e6,
    n_experts=8, top_k=2, d_expert=14336,
    source="arXiv:2401.04088; hf",
))

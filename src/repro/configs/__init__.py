"""Architecture registry — importing this package registers all configs."""
from repro.configs.base import (  # noqa: F401
    LayerSpec, ModelConfig, ShapeConfig, SHAPES, all_arch_names,
    cell_supported, get_config, register,
)

# one module per retained architecture (the serve-engine exemplars and the
# optimizer-variant test matrix); the other seed archs were deleted with
# the legacy training stack
from repro.configs import minicpm3_4b    # noqa: F401
from repro.configs import gemma3_1b      # noqa: F401
from repro.configs import yi_9b          # noqa: F401
from repro.configs import qwen2_moe_a27b # noqa: F401

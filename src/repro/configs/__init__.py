"""Architecture registry — importing this package registers all configs."""
from repro.configs.base import (  # noqa: F401
    LayerSpec, ModelConfig, ShapeConfig, SHAPES, all_arch_names,
    cell_supported, get_config, register,
)

# one module per assigned architecture
from repro.configs import internvl2_2b   # noqa: F401
from repro.configs import whisper_base   # noqa: F401
from repro.configs import minicpm3_4b    # noqa: F401
from repro.configs import gemma3_1b      # noqa: F401
from repro.configs import qwen2_72b      # noqa: F401
from repro.configs import yi_9b          # noqa: F401
from repro.configs import jamba_v01_52b  # noqa: F401
from repro.configs import mixtral_8x7b   # noqa: F401
from repro.configs import qwen2_moe_a27b # noqa: F401
from repro.configs import mamba2_13b     # noqa: F401

"""Architecture + workload-shape configuration.

A model is a stack of *groups*; each group is ``(pattern, repeats)`` where
``pattern`` is a tuple of LayerSpec applied in order and the group is
executed as a ``lax.scan`` over ``repeats`` stacked parameter copies
(compile-time O(pattern), not O(layers)).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any

import jax.numpy as jnp


def pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"          # attn | mla | mamba2 | none
    ffn: str = "mlp"             # mlp | moe | none
    window: int | None = None    # sliding-window size (attn only)
    cross_attn: bool = False     # decoder cross-attention (enc-dec)
    causal: bool = True          # False for encoder self-attention
    rope_theta: float | None = None  # per-layer override (gemma3 local/global)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    groups: tuple[tuple[tuple[LayerSpec, ...], int], ...]
    # attention
    qkv_bias: bool = False
    rope_theta: float = 1e4
    pos_embed: str = "rope"        # rope | learned | none
    max_seq: int = 524_288         # sizes the learned pos table if used
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    qk_norm: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    d_shared: int = 0              # shared-expert ffn width (0 = none)
    capacity_factor: float = 1.25
    # MLA (minicpm3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0
    # mamba2 / SSD
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # encoder-decoder (whisper)
    is_encdec: bool = False
    enc_groups: tuple = ()
    enc_seq: int = 1500            # stub frame-embedding length
    # vlm
    n_vision_tokens: int = 0       # stub patch-embedding prefix length
    # misc
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: Any = jnp.bfloat16
    # attention impl: 'auto' -> blockwise when seq > blockwise_min_seq
    attn_impl: str = "auto"
    blockwise_min_seq: int = 2048
    q_chunk: int = 512
    kv_chunk: int = 1024
    remat: str = "full"            # none | full | dots
    # --- beyond-paper optimization knobs (OFF for the faithful baseline;
    # enabled by dryrun --opt; see EXPERIMENTS.md §Perf) ---
    pad_heads_to: int = 0          # pad (MLA) heads for TP shardability
    pad_experts_to: int = 0        # pad expert count for expert parallelism
    banded_window_attn: bool = False  # band-limited attention for SWA layers
    kv_cache_int8: bool = False    # quantized KV cache (decode memory term)
    # sourcing tier from the assignment table
    source: str = ""

    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab, 256)

    @property
    def n_layers(self) -> int:
        return sum(len(p) * r for p, r in self.groups)

    @property
    def layer_list(self) -> list[LayerSpec]:
        out = []
        for pattern, r in self.groups:
            for _ in range(r):
                out.extend(pattern)
        return out

    @property
    def d_inner(self) -> int:  # mamba2
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def unroll(self) -> "ModelConfig":
        """Expand scan groups to repeat-1 groups (unrolled layers). Needed
        when layers contain shard_map (XLA-CPU CHECK-crashes on
        grad(scan(shard_map)) — see EXPERIMENTS.md §Perf/qwen2-moe)."""
        out = []
        for pattern, r in self.groups:
            out.extend(((pattern, 1),) * r)
        return replace(self, groups=tuple(out))

    def tiny(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        def shrink_groups(groups):
            out = []
            for pattern, r in groups:
                out.append((pattern, min(r, 2)))
            return tuple(out)

        return replace(
            self,
            d_model=64, n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=16, d_ff=128, vocab=503,  # prime vocab exercises padding
            groups=shrink_groups(self.groups),
            enc_groups=shrink_groups(self.enc_groups) if self.enc_groups else (),
            enc_seq=24 if self.is_encdec else self.enc_seq,
            n_vision_tokens=8 if self.n_vision_tokens else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            d_expert=32 if self.d_expert else 0,
            d_shared=64 if self.d_shared else 0,
            # drop-free capacity: token dropping is shape-dependent, which
            # would make decode-vs-forward equivalence tests meaningless
            capacity_factor=float(max(self.n_experts, 1)),
            q_lora_rank=32 if self.q_lora_rank else 0,
            kv_lora_rank=16 if self.kv_lora_rank else 0,
            qk_rope_dim=8 if self.qk_rope_dim else 0,
            qk_nope_dim=16 if self.qk_nope_dim else 0,
            v_head_dim=16 if self.v_head_dim else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=8,
            max_seq=4096,
            q_chunk=8, kv_chunk=16,
            dtype=jnp.float32, remat="none",
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode | long_decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "long_decode", 524_288, 1),
}


def uniform_groups(n_layers: int, spec: LayerSpec):
    return (((spec,), n_layers),)


_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # import triggers registration of all arch modules
    import repro.configs  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_arch_names() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)


# Which cells are skipped (long_500k on pure full-attention families).
LONG_CONTEXT_ARCHS = {"gemma3-1b", "jamba-v0.1-52b", "mixtral-8x7b", "mamba2-1.3b"}


def cell_supported(arch: str, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False, "full-attention family: 500k decode unsupported (DESIGN.md §5)"
    return True, ""

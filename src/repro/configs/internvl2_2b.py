"""internvl2-2b — InternViT frontend (stub) + InternLM2-1.8B LM backbone.
[arXiv:2404.16821; hf]  24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.
"""
from repro.configs.base import LayerSpec, ModelConfig, register, uniform_groups

CFG = register(ModelConfig(
    name="internvl2-2b",
    d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=92553,
    groups=uniform_groups(24, LayerSpec(mixer="attn", ffn="mlp")),
    rope_theta=1e6,
    n_vision_tokens=256,            # stub patch embeddings, prefix-injected
    source="arXiv:2404.16821; hf",
))

"""whisper-base — encoder-decoder; conv frontend is a STUB (input_specs
provides precomputed frame embeddings). [arXiv:2212.04356; unverified]
6L d_model=512 8H (MHA kv=8) d_ff=2048 vocab=51865.

Backbone-only per the assignment: decode_32k exercises a 32k self-attention
KV cache on the decoder (real whisper caps at 448 positions — we follow the
assigned shapes mechanically; see DESIGN.md §5).
"""
from repro.configs.base import LayerSpec, ModelConfig, register, uniform_groups

CFG = register(ModelConfig(
    name="whisper-base",
    d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
    d_ff=2048, vocab=51865,
    groups=uniform_groups(
        6, LayerSpec(mixer="attn", ffn="mlp", cross_attn=True)),
    is_encdec=True,
    enc_groups=uniform_groups(
        6, LayerSpec(mixer="attn", ffn="mlp", causal=False)),
    enc_seq=1500,
    pos_embed="learned", max_seq=32_768,
    norm="layernorm",
    source="arXiv:2212.04356; unverified",
))

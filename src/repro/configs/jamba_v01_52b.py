"""jamba-v0.1-52b — hybrid Mamba+attention 7:1 interleave, MoE 16e top-2.
[arXiv:2403.19887; hf]  32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536.  8-layer block x 4: attention at block index 4, MoE on odd
indices (16 MoE layers total). No explicit positional encoding (the Mamba
layers carry position). Jamba's Mamba-1 mixer is realized with our SSD mixer
at matching dims (d_state=16, d_conv=4, expand=2) — see DESIGN.md §3.
"""
from repro.configs.base import LayerSpec, ModelConfig, register

_m_mlp = LayerSpec(mixer="mamba2", ffn="mlp")
_m_moe = LayerSpec(mixer="mamba2", ffn="moe")
_a_mlp = LayerSpec(mixer="attn", ffn="mlp")
_m_moe2 = LayerSpec(mixer="mamba2", ffn="moe")

CFG = register(ModelConfig(
    name="jamba-v0.1-52b",
    d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=65536,
    groups=(
        ((_m_mlp, _m_moe, _m_mlp, _m_moe, _a_mlp, _m_moe, _m_mlp, _m_moe),
         4),
    ),
    pos_embed="none",
    n_experts=16, top_k=2, d_expert=14336,
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
    source="arXiv:2403.19887; hf",
))

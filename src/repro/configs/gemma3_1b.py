"""gemma3-1b — 5:1 local(sliding-window 512):global attention, 128k-class.
[hf:google/gemma-3-1b-pt; unverified]  26L d_model=1152 4H (GQA kv=1)
d_ff=6912 vocab=262144, head_dim=256, tied embeddings, QK-norm.
Pattern: (5 local + 1 global) x 4 + 2 local = 26 layers.
Local layers rope theta 10k; global layers 1M.
"""
from repro.configs.base import LayerSpec, ModelConfig, register

_local = LayerSpec(mixer="attn", ffn="mlp", window=512, rope_theta=1e4)
_global = LayerSpec(mixer="attn", ffn="mlp", rope_theta=1e6)

CFG = register(ModelConfig(
    name="gemma3-1b",
    d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab=262144,
    groups=(
        ((_local, _local, _local, _local, _local, _global), 4),
        ((_local, _local), 1),
    ),
    rope_theta=1e6,
    qk_norm=True,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt; unverified",
))

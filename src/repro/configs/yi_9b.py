"""yi-9b — llama-architecture dense GQA. [arXiv:2403.04652; hf]
48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from repro.configs.base import LayerSpec, ModelConfig, register, uniform_groups

CFG = register(ModelConfig(
    name="yi-9b",
    d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=11008, vocab=64000,
    groups=uniform_groups(48, LayerSpec(mixer="attn", ffn="mlp")),
    source="arXiv:2403.04652; hf",
))

"""qwen2-moe-a2.7b — 60 routed experts top-4 + shared expert (4x width).
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]  24L d_model=2048 16H (GQA kv=16)
d_ff=1408 (per-expert) vocab=151936; shared expert d_ff=5632.
"""
from repro.configs.base import LayerSpec, ModelConfig, register, uniform_groups

CFG = register(ModelConfig(
    name="qwen2-moe-a2.7b",
    d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab=151936,
    groups=uniform_groups(24, LayerSpec(mixer="attn", ffn="moe")),
    qkv_bias=True, rope_theta=1e6,
    n_experts=60, top_k=4, d_expert=1408, d_shared=5632,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B; hf",
))

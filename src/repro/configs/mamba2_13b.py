"""mamba2-1.3b — attention-free SSM with SSD (state-space duality).
[arXiv:2405.21060; unverified]  48L d_model=2048 vocab=50280 ssm_state=128,
expand=2 (d_inner=4096), head_dim=64 (64 SSD heads), tied embeddings.
"""
from repro.configs.base import LayerSpec, ModelConfig, register, uniform_groups

CFG = register(ModelConfig(
    name="mamba2-1.3b",
    d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,  # unused (no attn)
    d_ff=0, vocab=50280,
    groups=uniform_groups(48, LayerSpec(mixer="mamba2", ffn="none")),
    pos_embed="none",
    tie_embeddings=True,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
    source="arXiv:2405.21060; unverified",
))

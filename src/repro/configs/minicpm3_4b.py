"""minicpm3-4b — dense with Multi-head Latent Attention (MLA).
[hf:openbmb/MiniCPM3-4B; hf]  62L d_model=2560 40H d_ff=6400 vocab=73448.
MLA ranks: q_lora=768, kv_lora=256, qk_nope=64, qk_rope=32, v_head=64.
"""
from repro.configs.base import LayerSpec, ModelConfig, register, uniform_groups

CFG = register(ModelConfig(
    name="minicpm3-4b",
    d_model=2560, n_heads=40, n_kv_heads=40, head_dim=96,
    d_ff=6400, vocab=73448,
    groups=uniform_groups(62, LayerSpec(mixer="mla", ffn="mlp")),
    q_lora_rank=768, kv_lora_rank=256,
    qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
    source="hf:openbmb/MiniCPM3-4B; hf",
))

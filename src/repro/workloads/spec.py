"""Declarative workload specs for the lock-table simulator.

A :class:`Workload` describes *what the threads do* — per-thread (not
per-run) behavior — independently of how it is executed:

  * **locality** — ``P(target lock is on own node)`` as a scalar, a
    per-thread ``(T,)`` vector, or a named :func:`mixed` split (a fraction
    of each node's threads runs mostly-local, the rest mostly-remote);
  * **zipf_s** — Zipf skew of the within-node lock choice (hot keys);
  * **think** — think-time class between critical sections, either a named
    class from :data:`THINK_CLASSES` or a float multiplier of the cost
    model's ``think_ns``;
  * **cost** — the RDMA cost model the run executes under: ``None`` for
    the sweep default, a named :data:`~repro.core.cost_model.COST_PROFILES`
    entry (``"congested-nic"``, ``"idle-nic"``), an explicit
    :class:`~repro.core.cost_model.CostModel`, or a field-override mapping
    (``{"rnic_svc_ns": 900.0}``). Lowered to per-phase traced cost rows —
    swapping profiles never adds a compile;
  * **b_init** — the ALock ``(local, remote)`` lease budgets;
  * **phases** — piecewise regimes over the event axis (:class:`Phase`):
    each phase covers a fraction of the run and may override locality /
    skew / think / **cost** / **b_init** and take whole nodes down
    (``down_nodes`` — node join/leave churn). Threads of a downed node
    are simply never scheduled while the phase lasts. Per-phase ``cost``
    and ``b_init`` make the cost table and the budget *programs* over the
    run — e.g. a mid-run NIC-congestion burst, or a budget ramp.

  * **node_mult** — per-node fail-slow degradation: a multiplier applied
    to every cost the node *performs* (its local/poll/cs/think work and
    the RNIC service + wire of RDMA ops it serves). ``None`` means a
    uniform healthy cluster; a :data:`NODE_MULT_PROFILES` name or a
    ``{node: mult}`` mapping degrades specific nodes (the "limplock"
    effect — one slow NIC/CPU dragging the system). Per-phase overrides
    make degradation a *program* over the run (fail-slow cascades).
    Lowered to a traced ``(P, N)`` operand — swapping degradation
    patterns never adds a compile.

Specs are frozen and hashable, so they key result dicts the way the old
``SimConfig`` NamedTuple did. Execution knobs (events, seeds, backend,
devices) intentionally live elsewhere: ``repro.experiments`` composes
``Workload x seeds x ExecOptions`` into batched sweeps, and
``repro.workloads.lower`` turns a spec into the traced operand struct the
engines consume.

>>> w = Workload("alock", n_nodes=2, threads_per_node=2, n_locks=8,
...              b_init=(5, 20),
...              phases=(Phase(frac=0.5),
...                      Phase(frac=0.5, cost="congested-nic",
...                            b_init=(1, 1))))
>>> w.n_threads, w.n_phases
(4, 2)
>>> w == w.replace() and w != w.replace(seed=1)
True
>>> Workload("alock", 2, 2, 8, cost={"rnic_svc_ns": 900.0}).cost
(('rnic_svc_ns', 900.0),)
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

from repro.core.cost_model import freeze_cost

ALGS = ("alock", "spinlock", "mcs", "hlock", "alock-rw")

# Named think-time classes: multipliers of CostModel.think_ns. "default"
# is exactly the cost model's value (1.0), which the SimConfig adapter
# relies on for bitwise equality with the pre-spec front door.
THINK_CLASSES = {
    "none": 0.0,
    "short": 0.25,
    "default": 1.0,
    "long": 4.0,
}


def _check_prob(p, what: str) -> float:
    p = float(p)
    if not math.isfinite(p) or not 0.0 <= p <= 1.0:
        raise ValueError(f"{what} must be a probability in [0, 1], got {p}")
    return p


@dataclass(frozen=True)
class Mixed:
    """Per-node locality split: ``frac`` of each node's threads run at
    ``P(local) = local``, the remainder at ``P(local) = rest``."""
    local: float
    frac: float
    rest: float

    def __post_init__(self):
        _check_prob(self.local, "mixed(local=...)")
        _check_prob(self.frac, "mixed(frac=...)")
        _check_prob(self.rest, "mixed(rest=...)")


def mixed(local: float = 0.9, frac: float = 0.5, rest: float = 0.0) -> Mixed:
    """A named per-thread locality mix, e.g. ``mixed(local=0.9, frac=0.5)``:
    half of each node's threads target their own node 90% of the time, the
    other half is fully remote (``rest=0.0``)."""
    return Mixed(float(local), float(frac), float(rest))


def _freeze_locality(loc):
    """Scalar | (T,) sequence | Mixed -> hashable canonical form."""
    if isinstance(loc, Mixed):
        return loc
    if isinstance(loc, (tuple, list)):
        return tuple(_check_prob(v, "locality[t]") for v in loc)
    return _check_prob(loc, "locality")


def _freeze_read_frac(rf, what: str = "read_frac"):
    """Scalar | (T,) sequence | None -> hashable canonical form. The
    probability a request is a *read* — only the reader-writer machine
    (``alock-rw``) branches on it; write-only machines ignore it, so a
    leaderboard can hand every algorithm the same spec."""
    if rf is None:
        return None
    if isinstance(rf, (tuple, list)):
        return tuple(_check_prob(v, f"{what}[t]") for v in rf)
    return _check_prob(rf, what)


def freeze_topology(topo):
    """Validate + canonicalize a ``topology`` value (per-node rack ids).

    ``None`` means the trivial topology — every node its own rack — under
    which ``hlock`` degenerates to the flat two-cohort ALock (same-node =
    same-rack). A sequence gives one rack id per node; ids only need to
    be ``>= 0`` (equality is all the cohort test uses).
    """
    if topo is None:
        return None
    t = tuple(int(r) for r in topo)
    bad = [r for r in t if r < 0]
    if bad:
        raise ValueError(f"topology rack ids must be >= 0, got {bad}")
    return t


def racks_of(n_nodes: int, n_racks: int) -> tuple:
    """Evenly partition ``n_nodes`` into ``n_racks`` contiguous racks —
    the common cookbook shape for :attr:`Workload.topology`.

    >>> racks_of(8, 2)
    (0, 0, 0, 0, 1, 1, 1, 1)
    >>> racks_of(6, 4)
    (0, 0, 1, 1, 2, 3)
    """
    n_nodes, n_racks = int(n_nodes), int(n_racks)
    if not 1 <= n_racks <= n_nodes:
        raise ValueError(f"n_racks must be in [1, {n_nodes}], got {n_racks}")
    per, extra = divmod(n_nodes, n_racks)
    out = []
    for r in range(n_racks):
        out += [r] * (per + (1 if r < extra else 0))
    return tuple(out)


# Named fail-slow degradation profiles: {node: multiplier} patterns a
# Workload/Phase ``node_mult`` field can name instead of spelling out.
# 4x is the canonical "limping" severity — the limplock literature's
# cascading-slowdown regime sits between 3x and 10x single-node drag.
NODE_MULT_PROFILES: dict[str, dict[int, float]] = {
    "healthy": {},
    "limp-node0-2x": {0: 2.0},
    "limp-node0-4x": {0: 4.0},
}


def freeze_node_mult(nm):
    """Validate + canonicalize a ``node_mult`` value to its frozen form.

    ``None`` (uniform) and :data:`NODE_MULT_PROFILES` names pass through;
    a ``{node: mult}`` mapping (or pair iterable) becomes a sorted tuple
    of ``(node, mult)`` pairs. Multipliers must be finite and > 0 —
    a *dead* node is ``Phase.down_nodes``, not an infinite multiplier.
    """
    if nm is None:
        return None
    if isinstance(nm, str):
        if nm not in NODE_MULT_PROFILES:
            raise ValueError(f"unknown node_mult profile {nm!r}; "
                             f"registered: {sorted(NODE_MULT_PROFILES)}")
        return nm
    if isinstance(nm, dict):
        nm = tuple(sorted(nm.items()))
    if isinstance(nm, (tuple, list)):
        out = []
        for pair in nm:
            n, m = pair
            n, m = int(n), float(m)
            if n < 0:
                raise ValueError(f"node_mult node ids must be >= 0, got {n}")
            if not math.isfinite(m) or m <= 0.0:
                raise ValueError(f"node_mult multipliers must be finite "
                                 f"and > 0, got {m} for node {n}")
            out.append((n, m))
        if len({n for n, _ in out}) != len(out):
            raise ValueError("duplicate node ids in node_mult")
        return tuple(sorted(out))
    raise TypeError(f"node_mult must be None, a profile name, or a "
                    f"{{node: mult}} mapping, got {type(nm)!r}")


def node_mult_pairs(nm) -> tuple:
    """A ``node_mult`` value (raw or frozen) -> concrete ``(node, mult)``
    pairs (profile names resolved). ``None`` -> ``()``."""
    nm = freeze_node_mult(nm)
    if nm is None:
        return ()
    if isinstance(nm, str):
        return tuple(sorted(NODE_MULT_PROFILES[nm].items()))
    return nm


def resolve_node_mult(nm, n_nodes: int) -> tuple:
    """Frozen ``node_mult`` -> a dense ``(n_nodes,)`` multiplier tuple
    (1.0 everywhere a pair does not override) — the lowering's per-phase
    row of the traced ``(P, N)`` operand."""
    row = [1.0] * n_nodes
    for n, m in node_mult_pairs(nm):
        row[n] = m
    return tuple(row)


@dataclass(frozen=True)
class Arrivals:
    """Open-loop arrival stream: requests arrive, queue, acquire once and
    depart — instead of the closed loop's fixed thread pool re-acquiring
    forever (see ``docs/serving.md``).

    The stream is the *sum* of a deterministic base trace and a Poisson
    jitter term, which unifies the three spec shapes:

      * ``rate_per_us > 0`` with an empty trace — a Poisson process at the
        offered rate (phase-modulated via :attr:`Phase.rate_per_us`);
      * ``trace_ns`` non-empty with ``rate_per_us == 0`` — exact
        deterministic replay of recorded arrival times;
      * both — replay with Poisson-distributed per-request jitter.

    ``max_requests`` is the static request-slot count ``R`` (a shape, so
    it keys the compile bucket); a non-empty trace pins ``R`` to its
    length. Two admission policies lower to traced operands:
    ``queue_cap`` bounds the wait queue (tail drop, counted), and
    ``token_rate_per_us``/``token_burst`` gate admission through a token
    bucket (debit-on-arrival; a request entering with no token is
    dropped). ``None``/``0.0`` disables each policy.

    >>> Arrivals(rate_per_us=2.0, max_requests=64).n_requests
    64
    >>> Arrivals(trace_ns=(0, 500, 900)).n_requests
    3
    """
    rate_per_us: float = 0.0
    max_requests: int = 256
    trace_ns: tuple = ()
    queue_cap: int | None = None
    token_rate_per_us: float = 0.0
    token_burst: float = 8.0

    def __post_init__(self):
        r = float(self.rate_per_us)
        if not math.isfinite(r) or r < 0.0:
            raise ValueError(f"rate_per_us must be finite and >= 0, got {r}")
        object.__setattr__(self, "rate_per_us", r)
        mr = int(self.max_requests)
        if mr < 1:
            raise ValueError(f"max_requests must be >= 1, got {mr}")
        object.__setattr__(self, "max_requests", mr)
        tr = tuple(int(t) for t in self.trace_ns)
        if any(t < 0 for t in tr):
            raise ValueError("trace_ns times must be >= 0")
        if any(b < a for a, b in zip(tr, tr[1:])):
            raise ValueError("trace_ns must be non-decreasing")
        object.__setattr__(self, "trace_ns", tr)
        if r == 0.0 and not tr:
            raise ValueError("Arrivals needs rate_per_us > 0 or a trace_ns")
        if self.queue_cap is not None:
            qc = int(self.queue_cap)
            if qc < 0:
                raise ValueError(f"queue_cap must be >= 0, got {qc}")
            object.__setattr__(self, "queue_cap", qc)
        tkr = float(self.token_rate_per_us)
        if not math.isfinite(tkr) or tkr < 0.0:
            raise ValueError(
                f"token_rate_per_us must be finite and >= 0, got {tkr}")
        object.__setattr__(self, "token_rate_per_us", tkr)
        tkb = float(self.token_burst)
        if not math.isfinite(tkb) or tkb < 1.0:
            raise ValueError(f"token_burst must be >= 1, got {tkb}")
        object.__setattr__(self, "token_burst", tkb)

    @property
    def n_requests(self) -> int:
        """The static request-slot count ``R`` (trace length wins)."""
        return len(self.trace_ns) if self.trace_ns else self.max_requests


@dataclass(frozen=True)
class Phase:
    """One piecewise regime over the event axis.

    ``frac`` is the fraction of the run's events this phase covers (phase
    fractions must sum to 1). ``None`` overrides inherit the workload's
    base value. ``down_nodes`` lists node ids whose threads are parked
    (never scheduled) for the duration — node leave/join churn; at least
    one node must stay up. ``cost`` swaps the RDMA cost table for the
    phase (profile name / CostModel / field overrides — see
    :func:`~repro.core.cost_model.resolve_cost`); ``b_init`` re-programs
    the ALock ``(local, remote)`` budgets: acquisitions arming while the
    phase is live use the phase's budgets (the handoff is per-arm, not
    retroactive — a budget granted in phase *p* is spent down even after
    the boundary, until its holder re-arms); ``node_mult`` swaps the
    per-node fail-slow multipliers for the phase (degradation programs —
    a limp that spreads node-to-node across phases).
    """
    frac: float
    locality: object = None          # scalar | (T,) tuple | Mixed | None
    zipf_s: float | None = None
    think: object = None             # THINK_CLASSES name | float | None
    down_nodes: tuple = ()
    cost: object = None              # COST_PROFILES name | CostModel |
    #                                  override mapping | None (inherit)
    b_init: tuple | None = None      # (local, remote) | None (inherit)
    node_mult: object = None         # NODE_MULT_PROFILES name |
    #                                  {node: mult} mapping | None (inherit)
    rate_per_us: float | None = None  # open-loop arrival rate override
    #                                   (needs Workload.arrivals) | inherit
    read_frac: object = None         # scalar | (T,) tuple | None (inherit)
    #                                  P(request is a read) — alock-rw only

    def __post_init__(self):
        f = float(self.frac)
        if not math.isfinite(f) or f <= 0.0 or f > 1.0:
            raise ValueError(f"Phase.frac must be in (0, 1], got {self.frac}")
        object.__setattr__(self, "frac", f)
        object.__setattr__(self, "read_frac",
                           _freeze_read_frac(self.read_frac,
                                             "Phase.read_frac"))
        if self.rate_per_us is not None:
            r = float(self.rate_per_us)
            if not math.isfinite(r) or r < 0.0:
                raise ValueError(
                    f"Phase.rate_per_us must be finite and >= 0, got {r}")
            object.__setattr__(self, "rate_per_us", r)
        if self.locality is not None:
            object.__setattr__(self, "locality",
                               _freeze_locality(self.locality))
        object.__setattr__(self, "down_nodes",
                           tuple(int(n) for n in self.down_nodes))
        object.__setattr__(self, "cost", freeze_cost(self.cost))
        if self.b_init is not None:
            object.__setattr__(self, "b_init", _check_b_init(self.b_init))
        object.__setattr__(self, "node_mult",
                           freeze_node_mult(self.node_mult))


@dataclass(frozen=True)
class Workload:
    """Declarative simulator workload: topology + per-thread behavior.

    The spec is purely descriptive. ``repro.workloads.lower.lower`` turns
    it into the batched traced-operand struct (``WorkloadOperands``) that
    ``core/sim.py``, ``core/batch.py`` and ``kernels/event_loop`` consume,
    so sweeps mixing arbitrary localities / skews / phase programs share
    one compiled executable per ``(alg, T, N, K, n_events)`` shape bucket.
    """
    alg: str
    n_nodes: int
    threads_per_node: int
    n_locks: int
    locality: object = 1.0           # scalar | (T,) tuple | Mixed
    zipf_s: float = 0.0
    think: object = "default"        # THINK_CLASSES name | float multiplier
    b_init: tuple = (5, 20)          # (local, remote) budgets
    seed: int = 0
    phases: tuple = ()               # tuple[Phase, ...]
    cost: object = None              # COST_PROFILES name | CostModel |
    #                                  override mapping | None (sweep default)
    node_mult: object = None         # NODE_MULT_PROFILES name |
    #                                  {node: mult} mapping | None (uniform)
    arrivals: Arrivals | None = None  # open-loop request stream | None
    #                                   (closed loop — threads re-acquire)
    topology: tuple | None = None    # per-node rack ids (n_nodes,) | None
    #                                  (trivial: every node its own rack).
    #                                  Drives hlock's cohort test + cost
    #                                  tiers; inert for the flat machines.
    read_frac: object = 0.0          # scalar | (T,) tuple — P(read);
    #                                  branches alock-rw only, inert
    #                                  elsewhere (leaderboards share specs)

    def __post_init__(self):
        if self.alg not in ALGS:
            raise ValueError(f"alg must be one of {ALGS}, got {self.alg!r}")
        for name in ("n_nodes", "threads_per_node", "n_locks"):
            v = int(getattr(self, name))
            if v < 1:
                raise ValueError(f"{name} must be >= 1, got {v}")
            object.__setattr__(self, name, v)
        object.__setattr__(self, "locality", _freeze_locality(self.locality))
        zs = float(self.zipf_s)
        if not math.isfinite(zs) or zs < 0.0:
            raise ValueError(
                f"zipf_s must be finite and >= 0, got {self.zipf_s}")
        object.__setattr__(self, "zipf_s", zs)
        _check_think(self.think)
        object.__setattr__(self, "b_init", _check_b_init(self.b_init))
        object.__setattr__(self, "cost", freeze_cost(self.cost))
        object.__setattr__(self, "node_mult",
                           freeze_node_mult(self.node_mult))
        object.__setattr__(self, "seed", int(self.seed))
        topo = freeze_topology(self.topology)
        if topo is not None and len(topo) != self.n_nodes:
            raise ValueError(f"topology needs one rack id per node "
                             f"({self.n_nodes}), got {len(topo)}")
        object.__setattr__(self, "topology", topo)
        rf = _freeze_read_frac(self.read_frac)
        if rf is None:
            rf = 0.0
        object.__setattr__(self, "read_frac", rf)
        phases = tuple(self.phases)
        if phases:
            if not all(isinstance(p, Phase) for p in phases):
                raise ValueError("phases must be Phase instances")
            tot = sum(p.frac for p in phases)
            if abs(tot - 1.0) > 1e-6:
                raise ValueError(
                    f"phase fractions must sum to 1, got {tot:g}")
            for p in phases:
                bad = [n for n in p.down_nodes
                       if not 0 <= n < self.n_nodes]
                if bad:
                    raise ValueError(f"down_nodes {bad} outside "
                                     f"[0, {self.n_nodes})")
                if len(set(p.down_nodes)) >= self.n_nodes:
                    raise ValueError("a phase cannot take every node down")
        object.__setattr__(self, "phases", phases)
        if isinstance(self.locality, tuple) and \
                len(self.locality) != self.n_threads:
            raise ValueError(
                f"per-thread locality needs {self.n_threads} entries, "
                f"got {len(self.locality)}")
        for p in phases:
            if isinstance(p.locality, tuple) and \
                    len(p.locality) != self.n_threads:
                raise ValueError(
                    f"phase per-thread locality needs {self.n_threads} "
                    f"entries, got {len(p.locality)}")
        if isinstance(self.read_frac, tuple) and \
                len(self.read_frac) != self.n_threads:
            raise ValueError(
                f"per-thread read_frac needs {self.n_threads} entries, "
                f"got {len(self.read_frac)}")
        for p in phases:
            if isinstance(p.read_frac, tuple) and \
                    len(p.read_frac) != self.n_threads:
                raise ValueError(
                    f"phase per-thread read_frac needs {self.n_threads} "
                    f"entries, got {len(p.read_frac)}")
        # node_mult node ids are validated here (not in Phase) because
        # only the workload knows the topology — same split as down_nodes
        for what, nm in [("node_mult", self.node_mult)] + \
                [(f"phases[{i}].node_mult", p.node_mult)
                 for i, p in enumerate(phases)]:
            bad = [n for n, _ in node_mult_pairs(nm)
                   if not 0 <= n < self.n_nodes]
            if bad:
                raise ValueError(f"{what} node ids {bad} outside "
                                 f"[0, {self.n_nodes})")
        if self.arrivals is not None and \
                not isinstance(self.arrivals, Arrivals):
            raise TypeError(f"arrivals must be an Arrivals or None, "
                            f"got {type(self.arrivals)!r}")
        if self.arrivals is None:
            bad_ph = [i for i, p in enumerate(phases)
                      if p.rate_per_us is not None]
            if bad_ph:
                raise ValueError(
                    f"phases {bad_ph} set rate_per_us but the workload has "
                    f"no arrivals= stream (closed loop has no rate)")

    @property
    def n_threads(self) -> int:
        return self.n_nodes * self.threads_per_node

    @property
    def n_phases(self) -> int:
        return max(1, len(self.phases))

    def replace(self, **kw) -> "Workload":
        """A copy with fields replaced (phases/locality re-validated)."""
        return dataclasses.replace(self, **kw)


def _check_b_init(b) -> tuple:
    """Validate a (local, remote) ALock budget pair."""
    bi = tuple(int(v) for v in b)
    if len(bi) != 2:
        raise ValueError(f"b_init must be (local, remote), got {bi}")
    if any(v < 0 for v in bi):
        raise ValueError(f"b_init budgets must be >= 0, got {bi}")
    return bi


def _check_think(think) -> float:
    """Resolve a think class/multiplier to its float multiplier."""
    if isinstance(think, str):
        if think not in THINK_CLASSES:
            raise ValueError(f"unknown think class {think!r}; pick from "
                             f"{sorted(THINK_CLASSES)} or pass a float")
        return THINK_CLASSES[think]
    m = float(think)
    if not math.isfinite(m) or m < 0.0:
        raise ValueError(f"think multiplier must be finite and >= 0, got {m}")
    return m

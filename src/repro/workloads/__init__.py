"""Public workload API: declarative specs + lowering to traced operands.

>>> from repro.workloads import Workload, Phase, mixed
>>> w = Workload("alock", n_nodes=4, threads_per_node=8, n_locks=64,
...              locality=mixed(local=0.9, frac=0.5), zipf_s=1.2,
...              phases=(Phase(frac=0.5),
...                      Phase(frac=0.5, zipf_s=3.0)))   # hot-key storm

Run it with ``repro.experiments.Experiment`` (batched, labeled, with
error bars) or directly with ``repro.core.sim.simulate(w)``.
"""
from repro.workloads.lower import (Lowered, WorkloadOperands, as_workload,
                                   from_simconfig, lower, pad_phases,
                                   resolve_locality, zipf_cdf)
from repro.workloads.spec import (ALGS, Mixed, Phase, THINK_CLASSES,
                                  Workload, mixed)

__all__ = [
    "ALGS", "Lowered", "Mixed", "Phase", "THINK_CLASSES", "Workload",
    "WorkloadOperands", "as_workload", "from_simconfig", "lower", "mixed",
    "pad_phases", "resolve_locality", "zipf_cdf",
]

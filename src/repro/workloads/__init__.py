"""Public workload API: declarative specs + lowering to traced operands.

A :class:`Workload` says *what the threads do*; phases make every knob —
locality, Zipf skew, think class, the node set, the RDMA **cost profile**
and the ALock **budget pair** — a piecewise program over the run:

>>> from repro.workloads import Workload, Phase, mixed
>>> w = Workload("alock", n_nodes=4, threads_per_node=8, n_locks=64,
...              locality=mixed(local=0.9, frac=0.5), zipf_s=1.2,
...              phases=(Phase(frac=0.5),
...                      Phase(frac=0.5, zipf_s=3.0)))   # hot-key storm
>>> burst = Workload("alock", n_nodes=2, threads_per_node=2, n_locks=8,
...                  phases=(Phase(frac=0.5),
...                          Phase(frac=0.5, cost="congested-nic",
...                                b_init=(2, 40))))
>>> lw = lower(burst, n_events=1000)      # -> traced operand struct
>>> lw.operands.cost_rows.shape, lw.operands.b_init.shape
((2, 8), (2, 2))
>>> lw.shape_key                          # the compile bucket
('alock', 4, 2, 8, 1000, 0)

Run a spec with ``repro.experiments.Experiment`` (batched, labeled, with
error bars) or directly with ``repro.core.sim.simulate(w)``. Everything
workload-shaped lowers to *traced operands* (``WorkloadOperands``), so
sweeps mixing arbitrary specs of one shape bucket share one compiled
executable.
"""
from repro.core.cost_model import (COST_PROFILES, CostModel, CostProfile,
                                   resolve_cost)
from repro.workloads.lower import (Lowered, N_COST_ROWS, WorkloadOperands,
                                   as_workload, from_simconfig, lower,
                                   pad_phases, resolve_locality,
                                   resolve_read_frac, zipf_cdf)
from repro.workloads.spec import (ALGS, Arrivals, Mixed, NODE_MULT_PROFILES,
                                  Phase, THINK_CLASSES, Workload,
                                  freeze_node_mult, freeze_topology, mixed,
                                  node_mult_pairs, racks_of,
                                  resolve_node_mult)

__all__ = [
    "ALGS", "Arrivals", "COST_PROFILES", "CostModel", "CostProfile",
    "Lowered", "Mixed", "NODE_MULT_PROFILES", "N_COST_ROWS", "Phase",
    "THINK_CLASSES", "Workload", "WorkloadOperands", "as_workload",
    "freeze_node_mult", "freeze_topology", "from_simconfig", "lower",
    "mixed", "node_mult_pairs", "pad_phases", "racks_of", "resolve_cost",
    "resolve_locality", "resolve_node_mult", "resolve_read_frac",
    "zipf_cdf",
]

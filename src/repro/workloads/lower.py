"""Lowering: declarative :class:`Workload` specs -> traced operand structs.

``lower()`` turns a spec into a :class:`WorkloadOperands` — plain arrays,
*all of them traced operands* of the event-loop engines:

  ========== ========== ===================================================
  field      shape      meaning
  ========== ========== ===================================================
  locality   (P, T) f32 per-phase per-thread P(target lock is local)
  zcdf       (P, kpn)   per-phase inclusive Zipf CDF of the within-node draw
  edges      (P,) i32   first event index of each phase (edges[0] == 0)
  think_ns   (P,) i32   per-phase think time between critical sections
  active     (P, T) i32 1 = schedulable; 0 = thread's node is down
  b_init     (P, 2) i32 per-phase (local, remote) ALock budgets
  cost_rows  (P, 8) i32 per-phase cost-model rows (CostModel.cost_rows)
  seed       () i32     replica PRNG seed
  node_mult  (P, N) f32 per-phase per-node fail-slow cost multipliers
  arr_gap_ns (P,) f32   per-phase mean Poisson inter-arrival gap (0 = none)
  arr_edges  (P,) i32   first *request* index of each phase
  arr_qcap   (P,) i32   per-phase wait-queue bound (INT32_MAX = unbounded)
  arr_token  (P, 2) f32 per-phase token bucket (refill/ns, burst)
  arr_fix    (R,) i32   deterministic base inter-arrival gaps (trace replay)
  rack       (N,) i32   per-node rack id (hlock cohort/cost tiers; the
                        default ``arange(N)`` — every node its own rack —
                        makes hlock degenerate to the flat ALock)
  read_frac  (P, T) f32 per-phase per-thread P(request is a read) —
                        branches the alock-rw dispatch only
  ========== ========== ===================================================

Only ``(alg, T, N, K, n_events, R)`` — plus the phase-count P via the
operand *shapes* — is static, so a sweep mixing scenarios (different
localities, skews, phase programs, cost profiles, budget programs) shares
one compiled executable per shape bucket; ``pad_phases`` extends any
replica to a bucket's max P with unreachable phases (``edges =
INT32_MAX``), which provably never alters the per-event phase selection.

Open-loop arrival streams (``Workload.arrivals``) lower to the ``arr_*``
rows; ``R`` is the static request-slot count (``arr_fix.shape[-1]``) and
``R == 0`` *is* the closed loop — the arrival rows collapse to zero-work
placeholders and the engines trace the identical closed-loop program
(bitwise inertness, asserted in ``tests/test_traffic.py``). A request's
phase is its *index* interval (``arr_edges``), mirroring how events map to
phases, so rate programs modulate the stream without any in-loop coupling.

Cost and budget *programs*: every phase row carries its own 8-entry cost
table (resolved through :func:`~repro.core.cost_model.resolve_cost` from
the workload's / phase's ``cost`` field, defaulting to the sweep's
``CostModel``) and its own ``(local, remote)`` ALock budget pair (the
phase's ``b_init`` override, else the workload's). The engines index both
by the phase active at the event — a single-phase spec with default cost
lowers to exactly the rows ``sim.topology`` computed before profiles
existed, keeping that path bitwise-frozen.

``from_simconfig`` adapts the legacy flat ``SimConfig`` to a single-phase
``Workload`` bitwise-faithfully (same draws, costs, clocks).

>>> from repro.workloads import Workload, Phase, lower
>>> w = Workload("alock", n_nodes=2, threads_per_node=2, n_locks=8,
...              phases=(Phase(frac=0.5),
...                      Phase(frac=0.5, cost="congested-nic",
...                            b_init=(2, 40))))
>>> lw = lower(w, n_events=1000)
>>> lw.operands.cost_rows.shape, lw.operands.b_init.shape
((2, 8), (2, 2))
>>> lw.operands.b_init.tolist()          # phase 0 inherits the workload
[[5, 20], [2, 40]]
>>> bool((lw.operands.cost_rows[1] >= lw.operands.cost_rows[0]).all())
True
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import numpy as np

from repro.core.cost_model import CostModel, N_COST_ROWS, resolve_cost
from repro.workloads.spec import (Mixed, Phase, Workload, _check_think,
                                  resolve_node_mult)

_I32_MAX = np.iinfo(np.int32).max


class WorkloadOperands(NamedTuple):
    """The lowered, fully-traced workload (see module docstring for the
    per-field shapes). A jax pytree: ``batch.sweep`` stacks a leading
    replica axis B onto every leaf and vmaps the engines over it."""
    locality: Any   # (P, T) f32
    zcdf: Any       # (P, kpn) f32
    edges: Any      # (P,) i32
    think_ns: Any   # (P,) i32
    active: Any     # (P, T) i32
    b_init: Any     # (P, 2) i32
    seed: Any       # () i32
    cost_rows: Any  # (P, 8) i32
    node_mult: Any  # (P, N) f32
    arr_gap_ns: Any  # (P,) f32
    arr_edges: Any   # (P,) i32
    arr_qcap: Any    # (P,) i32
    arr_token: Any   # (P, 2) f32
    arr_fix: Any     # (R,) i32 — R == 0 means closed loop
    rack: Any        # (N,) i32 — per-node rack id (no phase axis)
    read_frac: Any   # (P, T) f32

    @property
    def n_phases(self) -> int:
        return self.edges.shape[-1]

    @property
    def n_requests(self) -> int:
        """Static request-slot count R (0 = closed loop)."""
        return self.arr_fix.shape[-1]


class Lowered(NamedTuple):
    """A spec bound to a run length: static shape info + operand arrays."""
    alg: str
    n_nodes: int
    threads_per_node: int
    n_locks: int
    n_events: int
    operands: WorkloadOperands      # numpy, no batch axis

    @property
    def n_threads(self) -> int:
        return self.n_nodes * self.threads_per_node

    @property
    def shape_key(self) -> tuple:
        """The static-argument tuple that determines a compile bucket."""
        return (self.alg, self.n_threads, self.n_nodes, self.n_locks,
                self.n_events, self.operands.n_requests)


def zipf_cdf(kpn: int, s: float) -> np.ndarray:
    """Inclusive CDF of a Zipf(s) draw over the ``kpn`` locks of one node.

    ``cdf[j] = P(lock_rank <= j)`` with ``P(rank j) ∝ (j+1)^-s``. Behavior
    notes the engines rely on:

      * ``s = 0`` is *exactly* the uniform workload in float32 —
        ``cdf[j] == float32((j+1)/kpn)`` bit for bit, so a zero-skew spec
        and the pre-Zipf engine draw identical locks;
      * the weights are normalized in float64 and only the cumulative sum
        is cast to float32, so ``cdf[-1] == 1.0`` exactly and the
        inverse-CDF draw can never walk past the last rank (the engines
        additionally clamp against the final-ulp case);
      * float32 so it can ride the traced batch axis next to ``locality``
        without recompiles.

    >>> zipf_cdf(4, 0.0).tolist()
    [0.25, 0.5, 0.75, 1.0]
    >>> float(zipf_cdf(8, 1.5)[-1])
    1.0
    """
    if kpn < 1:
        raise ValueError(f"need at least one lock per node, got kpn={kpn}")
    s = float(s)
    if not math.isfinite(s) or s < 0.0:
        raise ValueError(f"zipf skew must be finite and >= 0, got {s}")
    ranks = np.arange(1, kpn + 1, dtype=np.float64)
    w = ranks ** (-s)
    return np.cumsum(w / w.sum()).astype(np.float32)


def resolve_locality(loc, n_nodes: int, tpn: int) -> np.ndarray:
    """Scalar | (T,) tuple | Mixed -> the per-thread (T,) float32 vector."""
    T = n_nodes * tpn
    if isinstance(loc, Mixed):
        n_hot = int(round(loc.frac * tpn))
        row = np.full(tpn, np.float32(loc.rest))
        row[:n_hot] = np.float32(loc.local)
        return np.tile(row, n_nodes)
    if isinstance(loc, tuple):
        return np.asarray(loc, np.float32)
    return np.full(T, np.float32(loc))


def resolve_read_frac(rf, n_threads: int) -> np.ndarray:
    """Scalar | (T,) tuple -> the per-thread (T,) float32 read probability."""
    if isinstance(rf, tuple):
        return np.asarray(rf, np.float32)
    return np.full(n_threads, np.float32(rf))


def lower(w: Workload, n_events: int,
          cm: CostModel = CostModel()) -> Lowered:
    """Bind a spec to a run length and emit its traced operand struct.

    ``cm`` is the *sweep-level* cost model: the base every ``cost=None``
    workload/phase inherits. A workload-level ``cost`` replaces it for the
    whole run; a phase-level ``cost`` replaces it for that phase only.
    """
    N, tpn, K = w.n_nodes, w.threads_per_node, w.n_locks
    T = N * tpn
    if K % N != 0:
        raise ValueError(
            f"locks must partition evenly across nodes: n_locks={K} is not "
            f"a multiple of n_nodes={N} (got (n_locks, n_nodes)=({K}, {N}))")
    kpn = K // N
    phases = w.phases or (Phase(frac=1.0),)
    P = len(phases)
    base_cm = resolve_cost(w.cost, cm)

    arr = w.arrivals
    R = 0 if arr is None else arr.n_requests

    locality = np.empty((P, T), np.float32)
    zcdf = np.empty((P, kpn), np.float32)
    edges = np.empty(P, np.int32)
    think_ns = np.empty(P, np.int32)
    active = np.ones((P, T), np.int32)
    b_init = np.empty((P, 2), np.int32)
    cost_rows = np.empty((P, N_COST_ROWS), np.int32)
    node_mult = np.empty((P, N), np.float32)
    arr_gap_ns = np.zeros(P, np.float32)
    arr_edges = np.zeros(P, np.int32)
    arr_qcap = np.full(P, _I32_MAX, np.int32)
    arr_token = np.zeros((P, 2), np.float32)
    read_frac = np.empty((P, T), np.float32)
    # trivial default (every node its own rack): same-rack == same-node,
    # under which hlock is bitwise the flat ALock
    rack = (np.arange(N, dtype=np.int32) if w.topology is None
            else np.asarray(w.topology, np.int32))
    cum = 0.0
    for p, ph in enumerate(phases):
        edges[p] = int(round(cum * n_events))
        if arr is not None:
            # request index intervals mirror the event-phase mapping: the
            # phase's fraction of the run is its fraction of the stream
            arr_edges[p] = int(round(cum * R))
            rate = arr.rate_per_us if ph.rate_per_us is None \
                else ph.rate_per_us
            arr_gap_ns[p] = np.float32(1000.0 / rate) if rate > 0.0 else 0.0
            if arr.queue_cap is not None:
                arr_qcap[p] = arr.queue_cap
            if arr.token_rate_per_us > 0.0:
                arr_token[p] = (np.float32(arr.token_rate_per_us / 1000.0),
                                np.float32(arr.token_burst))
        cum += ph.frac
        loc = w.locality if ph.locality is None else ph.locality
        locality[p] = resolve_locality(loc, N, tpn)
        zs = w.zipf_s if ph.zipf_s is None else ph.zipf_s
        zcdf[p] = zipf_cdf(kpn, zs)
        cm_p = resolve_cost(ph.cost, base_cm)
        cost_rows[p] = cm_p.cost_rows(w.alg, N, tpn)
        b_init[p] = w.b_init if ph.b_init is None else ph.b_init
        mult = _check_think(w.think if ph.think is None else ph.think)
        # mult == 1.0 reproduces topology()'s c_think integer exactly —
        # the SimConfig adapter's bitwise contract rests on this
        think_ns[p] = int(round(mult * cm_p.think_ns))
        node_mult[p] = resolve_node_mult(
            w.node_mult if ph.node_mult is None else ph.node_mult, N)
        read_frac[p] = resolve_read_frac(
            w.read_frac if ph.read_frac is None else ph.read_frac, T)
        for node in ph.down_nodes:
            active[p, node * tpn:(node + 1) * tpn] = 0
    edges[0] = 0
    if arr is not None:
        arr_edges[0] = 0
    if arr is None:
        arr_fix = np.zeros(0, np.int32)
    elif arr.trace_ns:
        # absolute recorded times -> per-request base gaps (the additive
        # form lets a trace carry optional Poisson jitter on top)
        ts = np.asarray(arr.trace_ns, np.int64)
        gaps = np.diff(ts, prepend=0)
        if (gaps > _I32_MAX).any():
            raise ValueError("trace_ns inter-arrival gap overflows int32 ns")
        arr_fix = gaps.astype(np.int32)
    else:
        arr_fix = np.zeros(R, np.int32)
    if P == 1 and (active == 0).any():
        # the engines take a fast path (no phase/active machinery) for
        # single-phase operands, which is only sound when every thread is
        # schedulable — split a masked single phase into two identical
        # halves so the invariant "P == 1 implies all-active" holds by
        # construction (semantically identical: same mask both halves,
        # the boundary rejoin is a no-op)
        P = 2
        locality = np.repeat(locality, 2, axis=0)
        zcdf = np.repeat(zcdf, 2, axis=0)
        think_ns = np.repeat(think_ns, 2, axis=0)
        active = np.repeat(active, 2, axis=0)
        b_init = np.repeat(b_init, 2, axis=0)
        cost_rows = np.repeat(cost_rows, 2, axis=0)
        node_mult = np.repeat(node_mult, 2, axis=0)
        edges = np.asarray([0, n_events // 2], np.int32)
        arr_gap_ns = np.repeat(arr_gap_ns, 2, axis=0)
        arr_qcap = np.repeat(arr_qcap, 2, axis=0)
        arr_token = np.repeat(arr_token, 2, axis=0)
        arr_edges = np.asarray([0, R // 2], np.int32)
        read_frac = np.repeat(read_frac, 2, axis=0)
    if P > 1 and np.any(np.diff(edges) <= 0):
        # a zero-event phase would silently vanish AND misdirect the
        # rejoin bump at its boundary (was_act would read the dropped
        # phase's mask) — reject instead
        raise ValueError(
            f"phase program collapses at n_events={n_events}: edges "
            f"{edges.tolist()} are not strictly increasing (every phase "
            f"needs at least one event — raise n_events or merge phases)")

    ops = WorkloadOperands(
        locality=locality, zcdf=zcdf, edges=edges, think_ns=think_ns,
        active=active, b_init=b_init, seed=np.int32(w.seed),
        cost_rows=cost_rows, node_mult=node_mult,
        arr_gap_ns=arr_gap_ns, arr_edges=arr_edges, arr_qcap=arr_qcap,
        arr_token=arr_token, arr_fix=arr_fix, rack=rack,
        read_frac=read_frac)
    return Lowered(w.alg, N, tpn, K, int(n_events), ops)


def pad_phases(ops: WorkloadOperands, n_phases: int) -> WorkloadOperands:
    """Extend a replica's operands to ``n_phases`` with unreachable phases.

    Padded phases start at ``INT32_MAX`` (past any event index), so the
    per-event selection ``phase = sum(i >= edges) - 1`` is bitwise
    unchanged; their payload rows — locality, CDFs, think, active mask,
    budgets, cost rows, node multipliers — just duplicate the last real
    phase. Inertness of
    the cost/budget rows is load-bearing for one-compile-per-bucket
    sweeps and is asserted engine-level in the tests.
    """
    P = ops.n_phases
    if P == n_phases:
        return ops
    if P > n_phases:
        raise ValueError(f"cannot shrink {P} phases to {n_phases}")
    extra = n_phases - P

    def rep(a):
        return np.concatenate([a, np.repeat(a[-1:], extra, axis=0)], axis=0)

    return ops._replace(
        locality=rep(ops.locality), zcdf=rep(ops.zcdf),
        edges=np.concatenate([ops.edges,
                              np.full(extra, _I32_MAX, np.int32)]),
        think_ns=rep(ops.think_ns), active=rep(ops.active),
        b_init=rep(ops.b_init), cost_rows=rep(ops.cost_rows),
        node_mult=rep(ops.node_mult),
        # padded phases own no request-index interval, so their arrival
        # rows are unreachable by construction (arr_edges = INT32_MAX >
        # any request index); arr_fix is per-request, not per-phase
        arr_gap_ns=rep(ops.arr_gap_ns),
        arr_edges=np.concatenate([ops.arr_edges,
                                  np.full(extra, _I32_MAX, np.int32)]),
        arr_qcap=rep(ops.arr_qcap), arr_token=rep(ops.arr_token),
        # rack has no phase axis — pad-inert by construction
        read_frac=rep(ops.read_frac))


def from_simconfig(cfg) -> Workload:
    """Adapt a legacy flat ``SimConfig`` to a single-phase :class:`Workload`.

    .. deprecated::
        ``SimConfig`` is kept only as a compatibility front door;
        new code should construct :class:`Workload` (and
        ``repro.experiments.Experiment``) directly. Per-seed results
        through this adapter are bitwise-equal to the pre-spec engine
        on both backends (asserted in ``tests/test_workload_api.py``).
    """
    return Workload(
        alg=cfg.alg, n_nodes=cfg.n_nodes,
        threads_per_node=cfg.threads_per_node, n_locks=cfg.n_locks,
        locality=float(cfg.locality), zipf_s=float(cfg.zipf_s),
        b_init=tuple(cfg.b_init), seed=int(cfg.seed))


def as_workload(obj) -> Workload:
    """Coerce Workload | SimConfig-shaped NamedTuple -> Workload."""
    if isinstance(obj, Workload):
        return obj
    if hasattr(obj, "_fields") and hasattr(obj, "locality"):
        return from_simconfig(obj)
    raise TypeError(f"expected Workload or SimConfig, got {type(obj)!r}")

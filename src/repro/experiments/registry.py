"""Scenario registry: named, self-contained workload programs.

A *scenario* is a named function that builds and runs a workload program —
an :class:`~repro.experiments.experiment.Experiment` over the simulator,
or a threaded coordination-plane stress (``repro.coord.stress``) — and
returns CSV-able rows. The registry gives ``benchmarks.run --scenario``,
``benchmarks/perfcheck.py`` and CI one entry point: every registered name
is runnable with nothing but ``(n_seeds, n_events, options)``.

Rows are dicts with at least ``name`` / ``us_per_call`` / ``derived``
(the benchmark suite's CSV columns); simulator rows additionally carry
``p99_lat_ns`` / ``mean_mops``; extra keys ride into the JSON artifacts
(``BENCH_events_per_sec.json`` records them per row together with the
scenario name).

A scenario may declare an :class:`~repro.experiments.slo.Slo` — a
latency/throughput contract ``benchmarks.run --check-slo`` evaluates
against its rows and turns into an exit code (the CI scenarios leg runs
every scenario under the gate).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

from repro.experiments.experiment import Experiment
from repro.experiments.options import ExecOptions
from repro.experiments.slo import Slo
from repro.traffic.metrics import detect_knee
from repro.workloads import Arrivals, Phase, Workload, mixed, \
    racks_of, resolve_node_mult

_SCENARIOS: dict[str, "Scenario"] = {}


@dataclass(frozen=True)
class Scenario:
    name: str
    summary: str
    fn: Callable
    slo: Slo | None = None
    #: for simulator scenarios: a zero-arg callable returning the exact
    #: ``Workload`` specs the scenario sweeps — the differential test
    #: harness (``tests/test_event_loop_native_repr.py``) replays every
    #: one through the native-representation kernel and diffs it bitwise
    #: against the XLA engine. None for non-simulator scenarios
    #: (coord-stress drives the threaded coordination plane instead).
    workloads: Callable | None = None


def scenario(name: str, summary: str, slo: Slo | None = None,
             workloads: Callable | None = None):
    """Register ``fn(n_seeds, n_events, options) -> list[dict]``, with an
    optional :class:`Slo` the ``--check-slo`` gate enforces and an
    optional ``workloads()`` builder exposing the swept specs."""
    def deco(fn):
        if name in _SCENARIOS:
            raise ValueError(f"scenario {name!r} already registered")
        _SCENARIOS[name] = Scenario(name, summary, fn, slo, workloads)
        return fn
    return deco


def scenario_workloads(name: str):
    """The ``Workload`` specs a simulator scenario sweeps (None when the
    scenario does not drive the event simulator)."""
    sc = get_scenario(name)
    return None if sc.workloads is None else list(sc.workloads())


def scenario_names() -> list[str]:
    return sorted(_SCENARIOS)


def get_scenario(name: str) -> Scenario:
    try:
        return _SCENARIOS[name]
    except KeyError:
        raise ValueError(f"unknown scenario {name!r}; registered: "
                         f"{scenario_names()}") from None


def run_scenario(name: str, n_seeds: int = 1, n_events: int = 150_000,
                 options: ExecOptions = ExecOptions()) -> list[dict]:
    return get_scenario(name).fn(n_seeds, n_events, options)


# ---------------------------------------------------------------------------
# built-ins

# the common mid-size topology the sim scenarios share: one shape bucket
# per algorithm no matter how phases / localities / skews vary
_BASE = Workload("alock", n_nodes=4, threads_per_node=4, n_locks=16,
                 locality=0.95)


def _phase_mults(w: Workload) -> list[tuple]:
    """Dense per-phase ``(n_nodes,)`` multiplier rows of a spec."""
    base = w.node_mult
    phases = w.phases or (Phase(frac=1.0),)
    return [resolve_node_mult(p.node_mult if p.node_mult is not None
                              else base, w.n_nodes) for p in phases]


def _rows(result) -> list[dict]:
    out = []
    for lbl, w, br in result:
        out.append({
            "name": lbl, "us_per_call": br.mean_lat_us,
            "derived": f"{br.mean_mops:.3f}±{br.ci95_mops:.3f}Mops",
            "alg": w.alg,
            "mean_mops": br.mean_mops, "ci95_mops": br.ci95_mops,
            "p99_lat_ns": br.p99_lat_ns,
            "ops": int(br.ops.sum()),
        })
        # under non-uniform fail-slow degradation a per-alg aggregate
        # hides exactly the asymmetry the scenario exists to show — break
        # the throughput out per node (op-share weighted)
        mults = _phase_mults(w)
        if any(m != 1.0 for row in mults for m in row):
            pto = br.per_thread_ops.sum(axis=0)
            total = max(float(pto.sum()), 1e-9)
            tpn = w.threads_per_node
            for n in range(w.n_nodes):
                share = float(pto[n * tpn:(n + 1) * tpn].sum()) / total
                xmax = max(row[n] for row in mults)
                out.append({
                    "name": f"{lbl}.node{n}", "us_per_call": 0.0,
                    "derived": (f"{br.mean_mops * share:.3f}Mops "
                                f"({share:.3f} share, x{xmax:g})"),
                    "node_mops": br.mean_mops * share,
                    "node_op_share": share, "node_mult_max": xmax,
                })
    return out


# spec-building constants shared by each scenario fn and its registered
# ``workloads`` builder, so the differential harness replays the *exact*
# specs the scenario sweeps (no drift between the two)
_UNIFORM_AXES = dict(alg=("alock", "spinlock", "mcs"),
                     locality=(0.85, 0.95, 1.0))
_STORM = (Phase(frac=0.4), Phase(frac=0.2, zipf_s=3.0), Phase(frac=0.4))
_MIX_FRACS = (0.25, 0.5, 0.75)
_CHURN = (Phase(frac=0.3), Phase(frac=0.4, down_nodes=(3,)),
          Phase(frac=0.3))
_NIC_BURST = (Phase(frac=0.3), Phase(frac=0.4, cost="congested-nic"),
              Phase(frac=0.3))
_RAMP = (Phase(frac=0.34, b_init=(1, 1)), Phase(frac=0.33),
         Phase(frac=0.33, b_init=(20, 80)))
_RAMP_BASE = _BASE.replace(locality=0.9)
# fail-slow: node 0 limps at 4x. "hot" places the traffic on the limping
# node (its own threads hammer their local locks, everyone else's remote
# traffic spreads across nodes incl. node 0); "cold" steers all steady
# traffic away from node 0's locks (its threads go fully remote, everyone
# else fully local) — the limp then only taxes work node 0 itself performs.
_LIMP = "limp-node0-4x"
_TPN = _BASE.threads_per_node
_T = _BASE.n_nodes * _TPN
_LIMP_HOT = (1.0,) * _TPN + (0.0,) * (_T - _TPN)
_LIMP_COLD = (0.0,) * _TPN + (1.0,) * (_T - _TPN)
# degradation spreading node-to-node over the run; node 3 stays healthy
_CASCADE = (Phase(frac=0.25),
            Phase(frac=0.25, node_mult={0: 4.0}),
            Phase(frac=0.25, node_mult={0: 4.0, 1: 4.0}),
            Phase(frac=0.25, node_mult={0: 4.0, 1: 4.0, 2: 4.0}))
# open-loop ramp: offered Poisson rates bracketing every algorithm's
# measured service capacity on the shared topology (~9 req/us alock,
# ~2.1 mcs, ~2.3 spinlock) so detect_knee lands inside the sweep for each.
# R stays modest — the kernel pays O(R) lanes per event step — and the
# bounded queue makes overload shed load instead of completing everything
# eventually (an event-bounded run with an unbounded queue drains its
# backlog, which would hide the knee).
_RAMP_RATES = (0.5, 1.0, 2.0, 4.0, 8.0, 16.0)
_RAMP_REQS = 256
_RAMP_QCAP = 32
_OPEN_ALGS = ("alock", "mcs", "spinlock")
# burst-storm: steady 1 req/us with a mid-run 12 req/us spike (phased
# rate program), absorbed by three admission policies per algorithm
_BURST_PH = (Phase(frac=0.4), Phase(frac=0.2, rate_per_us=12.0),
             Phase(frac=0.4))
_BURST_POLICIES = (
    ("open", Arrivals(rate_per_us=1.0, max_requests=_RAMP_REQS)),
    ("queue16", Arrivals(rate_per_us=1.0, max_requests=_RAMP_REQS,
                         queue_cap=16)),
    ("token", Arrivals(rate_per_us=1.0, max_requests=_RAMP_REQS,
                       token_rate_per_us=2.0, token_burst=16.0)),
)
# read-heavy: alock-rw at increasing read mixes against the writer-only
# alock control on the identical spec — readers share the CS, so the
# throughput ratio should grow with the read fraction and dominate by 0.9
_READ_FRACS = (0.5, 0.9, 0.99)
# rack-locality: two racks of two nodes each (racks_of(4, 2)); hlock's
# rack cohort merges each rack into one Peterson side, discounting
# in-rack remote traffic (loopback-priced) at the cost of coarser lease
# handoffs — the sweep brackets where each effect wins
_RACKS = racks_of(_BASE.n_nodes, 2)
_RACK_LOCS = (0.5, 0.75, 0.95)


def _rw_label(rf: float) -> str:
    return f"alock-rw.rf{int(rf * 100)}"


def _uniform_grid_workloads():
    import itertools
    return [_BASE.replace(alg=a, locality=l)
            for a, l in itertools.product(*_UNIFORM_AXES.values())]


def _hot_key_storm_workloads():
    return [w for alg in ("alock", "mcs")
            for w in (_BASE.replace(alg=alg),
                      _BASE.replace(alg=alg, phases=_STORM))]


def _mixed_locality_workloads():
    return [_BASE] + [_BASE.replace(locality=mixed(local=0.95, frac=f,
                                                   rest=0.5))
                      for f in _MIX_FRACS]


def _node_churn_workloads():
    return [_BASE, _BASE.replace(phases=_CHURN)]


def _congested_nic_workloads():
    return [w for alg in ("alock", "mcs")
            for w in (_BASE.replace(alg=alg),
                      _BASE.replace(alg=alg, phases=_NIC_BURST),
                      _BASE.replace(alg=alg, cost="congested-nic"))]


def _budget_ramp_workloads():
    return [_RAMP_BASE, _RAMP_BASE.replace(b_init=(1, 1)),
            _RAMP_BASE.replace(phases=_RAMP)]


def _limping_node_workloads():
    return [_BASE.replace(alg=alg, locality=loc, node_mult=nm)
            for alg in ("alock", "mcs")
            for loc in (_LIMP_HOT, _LIMP_COLD)
            for nm in (None, _LIMP)]


def _fail_slow_cascade_workloads():
    return [w for alg in ("alock", "mcs")
            for w in (_BASE.replace(alg=alg),
                      _BASE.replace(alg=alg, phases=_CASCADE))]


def _open_loop_ramp_workloads():
    return [_BASE.replace(alg=alg,
                          arrivals=Arrivals(rate_per_us=r,
                                            max_requests=_RAMP_REQS,
                                            queue_cap=_RAMP_QCAP))
            for alg in _OPEN_ALGS for r in _RAMP_RATES]


def _burst_storm_workloads():
    return [_BASE.replace(alg=alg, phases=_BURST_PH, arrivals=arr)
            for alg in ("alock", "mcs") for _, arr in _BURST_POLICIES]


def _read_heavy_workloads():
    return [_BASE] + [_BASE.replace(alg="alock-rw", read_frac=rf)
                      for rf in _READ_FRACS]


def _rack_locality_workloads():
    return [_BASE.replace(alg=alg, locality=loc,
                          topology=_RACKS if alg == "hlock" else None)
            for alg in ("alock", "hlock", "mcs") for loc in _RACK_LOCS]


def _serving_rows(label: str, br) -> dict:
    """One serving row per open-loop workload (seed-averaged)."""
    sm = br.serving_mean()
    return {
        "name": f"{label}.serving", "us_per_call": 0.0,
        "derived": (f"{sm['goodput_per_us']:.3f}/"
                    f"{sm['offered_per_us']:.3f} req/us, "
                    f"drop {sm['drop_rate']:.3f}"),
        "offered_per_us": sm["offered_per_us"],
        "goodput_per_us": sm["goodput_per_us"],
        "drop_rate": sm["drop_rate"],
        "completed": sm["completed"], "dropped": sm["dropped"],
        "p99_sojourn_ns": sm["p99_sojourn_ns"],
        "mean_wait_ns": sm["mean_wait_ns"],
        "mean_concurrency": sm["mean_concurrency"],
    }


@scenario("uniform-grid",
          "alg x locality grid on the shared 4-node topology",
          workloads=_uniform_grid_workloads)
def _uniform_grid(n_seeds, n_events, options):
    exp = Experiment("uniform-grid", n_seeds=n_seeds, n_events=n_events,
                     options=options)
    exp.add_grid(_BASE, **_UNIFORM_AXES)
    return _rows(exp.run())


@scenario("hot-key-storm",
          "mid-run Zipf(3) burst vs steady uniform traffic (phased)",
          workloads=_hot_key_storm_workloads)
def _hot_key_storm(n_seeds, n_events, options):
    exp = Experiment("hot-key-storm", n_seeds=n_seeds, n_events=n_events,
                     options=options)
    for alg in ("alock", "mcs"):
        exp.add(_BASE.replace(alg=alg), label=f"{alg}.steady")
        exp.add(_BASE.replace(alg=alg, phases=_STORM), label=f"{alg}.storm")
    res = exp.run()
    rows = _rows(res)
    for alg in ("alock", "mcs"):
        hit = res[f"{alg}.storm"].mean_mops / \
            max(res[f"{alg}.steady"].mean_mops, 1e-9)
        rows.append({"name": f"{alg}.storm_throughput_ratio",
                     "us_per_call": 0.0, "derived": f"{hit:.3f}x",
                     "ratio": hit})
    return rows


@scenario("mixed-locality",
          "per-thread locality splits (mixed(local, frac, rest)) vs flat",
          workloads=_mixed_locality_workloads)
def _mixed_locality(n_seeds, n_events, options):
    exp = Experiment("mixed-locality", n_seeds=n_seeds, n_events=n_events,
                     options=options)
    flat, *mixes = _mixed_locality_workloads()
    exp.add(flat, label="flat95")
    for frac, w in zip(_MIX_FRACS, mixes):
        exp.add(w, label=f"mix{int(frac * 100)}")
    return _rows(exp.run())


@scenario("node-churn",
          "a node leaves mid-run and rejoins (phased active mask)",
          workloads=_node_churn_workloads)
def _node_churn(n_seeds, n_events, options):
    exp = Experiment("node-churn", n_seeds=n_seeds, n_events=n_events,
                     options=options)
    exp.add(_BASE, label="steady")
    exp.add(_BASE.replace(phases=_CHURN), label="churn")
    res = exp.run()
    rows = _rows(res)
    pto = res["churn"].per_thread_ops.sum(axis=0)   # (T,) over seeds
    tpn = _BASE.threads_per_node
    share = float(pto[3 * tpn:4 * tpn].sum()) / max(float(pto.sum()), 1e-9)
    rows.append({"name": "churn.node3_op_share", "us_per_call": 0.0,
                 "derived": f"{share:.3f} (vs {1 / 4:.3f} steady)",
                 "node3_share": share})
    return rows


@scenario("congested-nic",
          "mid-run NIC-congestion burst (phased cost profile); SLO-gated",
          slo=Slo(p99_ns=2_000_000, min_events_per_sec=10.0),
          workloads=_congested_nic_workloads)
def _congested_nic(n_seeds, n_events, options):
    """The phase-dependent cost model in anger: the middle 40% of the run
    executes under the ``congested-nic`` profile (card past its
    serialization point, inflated wire + PCIe pressure). ALock's
    local-majority traffic never touches the RNIC, so it should shrug the
    burst off while loopback designs (mcs) pay full freight — the same
    asymmetry behind the paper's 29x headline, but driven as a transient.
    """
    exp = Experiment("congested-nic", n_seeds=n_seeds, n_events=n_events,
                     options=options)
    for alg in ("alock", "mcs"):
        exp.add(_BASE.replace(alg=alg), label=f"{alg}.steady")
        exp.add(_BASE.replace(alg=alg, phases=_NIC_BURST),
                label=f"{alg}.congested")
        exp.add(_BASE.replace(alg=alg, cost="congested-nic"),
                label=f"{alg}.always-congested")
    res = exp.run()
    rows = _rows(res)
    for alg in ("alock", "mcs"):
        hit = res[f"{alg}.congested"].mean_mops / \
            max(res[f"{alg}.steady"].mean_mops, 1e-9)
        rows.append({"name": f"{alg}.congestion_throughput_ratio",
                     "us_per_call": 0.0, "derived": f"{hit:.3f}x",
                     "ratio": hit})
    return rows


@scenario("budget-ramp",
          "ALock lease-budget program: tight -> paper -> generous phases",
          slo=Slo(p99_ns=2_000_000, min_events_per_sec=10.0),
          workloads=_budget_ramp_workloads)
def _budget_ramp(n_seeds, n_events, options):
    """The per-phase ``b_init`` program: a run that starts with
    pathologically tight budgets (every handoff re-arms at 1 — constant
    pReacquire churn, Fig. 4's left edge), transitions to the paper's
    (5, 20) tuning, then to generous budgets. Throughput should recover
    along the ramp while the constant-tight control keeps paying; the
    reacquire counters expose the mechanism.
    """
    exp = Experiment("budget-ramp", n_seeds=n_seeds, n_events=n_events,
                     options=options)
    exp.add(_RAMP_BASE, label="paper-budget")
    exp.add(_RAMP_BASE.replace(b_init=(1, 1)), label="tight-budget")
    exp.add(_RAMP_BASE.replace(phases=_RAMP), label="ramp")
    res = exp.run()
    rows = _rows(res)
    for lbl in ("paper-budget", "tight-budget", "ramp"):
        rows.append({"name": f"{lbl}.reacquires", "us_per_call": 0.0,
                     "derived": f"{res[lbl].reacquires.mean():.0f}",
                     "reacquires": float(res[lbl].reacquires.mean())})
    return rows


@scenario("limping-node",
          "one 4x fail-slow node hosting hot vs cold locks; SLO-gated",
          slo=Slo(p99_ns=500_000, min_events_per_sec=10.0),
          workloads=_limping_node_workloads)
def _limping_node(n_seeds, n_events, options):
    """The limplock regime: node 0's card serves every request at 4x
    (``node_mult="limp-node0-4x"``) while the cluster stays up. Placement
    decides the blast radius — with the *hot* locks on the limping node
    every client queues behind the slow card, with them *cold* only node
    0's own work drags. ALock's lease handoffs keep the hot path local to
    each holder, so it degrades by the single slow participant; MCS
    loopback traffic pays the slow card on every hop.
    """
    exp = Experiment("limping-node", n_seeds=n_seeds, n_events=n_events,
                     options=options)
    for alg in ("alock", "mcs"):
        for place, loc in (("hot", _LIMP_HOT), ("cold", _LIMP_COLD)):
            exp.add(_BASE.replace(alg=alg, locality=loc),
                    label=f"{alg}.{place}.healthy")
            exp.add(_BASE.replace(alg=alg, locality=loc, node_mult=_LIMP),
                    label=f"{alg}.{place}.limp")
    res = exp.run()
    rows = _rows(res)
    for alg in ("alock", "mcs"):
        for place in ("hot", "cold"):
            hit = res[f"{alg}.{place}.limp"].mean_mops / \
                max(res[f"{alg}.{place}.healthy"].mean_mops, 1e-9)
            rows.append({"name": f"{alg}.{place}.limp_throughput_ratio",
                         "us_per_call": 0.0, "derived": f"{hit:.3f}x",
                         "ratio": hit})
    return rows


@scenario("fail-slow-cascade",
          "degradation spreading node-to-node over the run; SLO-gated",
          slo=Slo(p99_ns=300_000, min_events_per_sec=10.0),
          workloads=_fail_slow_cascade_workloads)
def _fail_slow_cascade(n_seeds, n_events, options):
    """A fail-slow *program*: the run starts healthy, then node 0 limps
    at 4x, then node 1 joins it, then node 2 — only node 3 stays healthy
    by the final quarter (the cascading-slowdown pattern from the
    limplock literature, where one degraded NIC backs up its peers). The
    per-phase ``node_mult`` rows make the spread a single compiled
    executable; the ratio rows track how much of the healthy baseline
    each algorithm keeps as the cascade widens.
    """
    exp = Experiment("fail-slow-cascade", n_seeds=n_seeds,
                     n_events=n_events, options=options)
    for alg in ("alock", "mcs"):
        exp.add(_BASE.replace(alg=alg), label=f"{alg}.healthy")
        exp.add(_BASE.replace(alg=alg, phases=_CASCADE),
                label=f"{alg}.cascade")
    res = exp.run()
    rows = _rows(res)
    for alg in ("alock", "mcs"):
        hit = res[f"{alg}.cascade"].mean_mops / \
            max(res[f"{alg}.healthy"].mean_mops, 1e-9)
        rows.append({"name": f"{alg}.cascade_throughput_ratio",
                     "us_per_call": 0.0, "derived": f"{hit:.3f}x",
                     "ratio": hit})
    return rows


@scenario("open-loop-ramp",
          "offered-load ramp through each algorithm's saturation knee",
          slo=Slo(p99_ns=2_000_000, min_events_per_sec=10.0),
          workloads=_open_loop_ramp_workloads)
def _open_loop_ramp(n_seeds, n_events, options):
    """Open-loop serving curves: a Poisson arrival stream at each rate in
    ``_RAMP_RATES`` (bounded queue, tail drop) per algorithm. Below the
    knee goodput tracks the offered rate; above it the queue overflows
    and the gap plus the drop counters absorb the difference. The knee
    rows report where ``detect_knee`` places each algorithm's saturation
    point — ALock's local-handoff capacity (~9 req/us here) sits well
    above the loopback designs (~2 req/us), which is the serving-path
    view of the paper's throughput asymmetry.
    """
    exp = Experiment("open-loop-ramp", n_seeds=n_seeds, n_events=n_events,
                     options=options)
    for w in _open_loop_ramp_workloads():
        exp.add(w, label=f"{w.alg}.rate{w.arrivals.rate_per_us:g}")
    res = exp.run()
    rows = _rows(res)
    for lbl, _, br in res:
        rows.append(_serving_rows(lbl, br))
    for alg in _OPEN_ALGS:
        sms = [res[f"{alg}.rate{r:g}"].serving_mean() for r in _RAMP_RATES]
        knee = detect_knee([s["offered_per_us"] for s in sms],
                           [s["goodput_per_us"] for s in sms])
        cap = sms[knee]["goodput_per_us"] if knee is not None else None
        rows.append({
            "name": f"{alg}.knee", "us_per_call": 0.0,
            "derived": (f"knee @ {_RAMP_RATES[knee]:g} req/us offered, "
                        f"~{cap:.2f} served" if knee is not None
                        else "no knee in ramp"),
            "knee_rate_per_us": (None if knee is None
                                 else _RAMP_RATES[knee]),
            "knee_goodput_per_us": cap,
        })
    return rows


@scenario("burst-storm",
          "12x arrival-rate spike vs bounded-queue/token admission",
          slo=Slo(p99_ns=2_000_000, min_events_per_sec=10.0),
          workloads=_burst_storm_workloads)
def _burst_storm(n_seeds, n_events, options):
    """Phase-modulated open loop: the middle 20% of the run offers 12
    req/us against a 1 req/us baseline. The ``open`` control admits
    everything and rides the backlog down; ``queue16`` tail-drops once
    16 requests wait (bounding queue delay at the cost of goodput);
    ``token`` debits a 2 req/us token bucket on arrival, shaving the
    burst before it ever queues. The drop-split rows show which policy
    sheds the storm and what p99 sojourn that buys.
    """
    exp = Experiment("burst-storm", n_seeds=n_seeds, n_events=n_events,
                     options=options)
    for alg in ("alock", "mcs"):
        for pol, arr in _BURST_POLICIES:
            exp.add(_BASE.replace(alg=alg, phases=_BURST_PH, arrivals=arr),
                    label=f"{alg}.{pol}")
    res = exp.run()
    rows = _rows(res)
    for lbl, _, br in res:
        rows.append(_serving_rows(lbl, br))
    for alg in ("alock", "mcs"):
        base = res[f"{alg}.open"].serving_mean()
        for pol in ("queue16", "token"):
            sm = res[f"{alg}.{pol}"].serving_mean()
            ratio = sm["goodput_per_us"] / max(base["goodput_per_us"], 1e-9)
            rows.append({
                "name": f"{alg}.{pol}.vs_open", "us_per_call": 0.0,
                "derived": (f"{ratio:.3f}x goodput, "
                            f"drop {sm['drop_rate']:.3f}"),
                "goodput_ratio": ratio, "drop_rate": sm["drop_rate"],
            })
    return rows


@scenario("read-heavy",
          "alock-rw read mixes (0.5/0.9/0.99) vs writer-only alock; "
          "SLO-gated per label",
          slo=Slo(p99_ns=500_000, min_events_per_sec=10.0,
                  per_label={"alock-rw.rf99": Slo(p99_ns=100_000)}),
          workloads=_read_heavy_workloads)
def _read_heavy(n_seeds, n_events, options):
    """The reader/writer split under increasing read mixes: the same spec
    runs writer-only under plain ``alock`` and under ``alock-rw`` with
    read fractions 0.5 / 0.9 / 0.99. Readers share the critical section
    (writers drain them first and keep exclusivity), so throughput climbs
    with the read mix and should dominate the writer-only control by
    read_frac >= 0.9 — the vs_alock ratio rows state the claim directly,
    and the per-label SLO pins the near-read-only latency tail.
    """
    exp = Experiment("read-heavy", n_seeds=n_seeds, n_events=n_events,
                     options=options)
    exp.add(_BASE, label="alock.writer-only")
    for rf in _READ_FRACS:
        exp.add(_BASE.replace(alg="alock-rw", read_frac=rf),
                label=_rw_label(rf))
    res = exp.run()
    rows = _rows(res)
    base = max(res["alock.writer-only"].mean_mops, 1e-9)
    for rf in _READ_FRACS:
        hit = res[_rw_label(rf)].mean_mops / base
        rows.append({"name": f"rf{int(rf * 100)}.vs_alock_ratio",
                     "us_per_call": 0.0, "derived": f"{hit:.3f}x",
                     "ratio": hit, "read_frac": rf})
    return rows


@scenario("rack-locality",
          "hlock's rack cohorts vs flat alock across a locality sweep; "
          "SLO-gated per label",
          slo=Slo(p99_ns=500_000, min_events_per_sec=10.0,
                  per_label={"hlock.loc50": Slo(p99_ns=200_000)}),
          workloads=_rack_locality_workloads)
def _rack_locality(n_seeds, n_events, options):
    """The hierarchical cohort trade-off, swept over locality on a
    two-rack topology (``racks_of(4, 2)``). hlock prices same-rack remote
    traffic as loopback instead of full RDMA but merges each rack into
    one Peterson cohort, so half its "local"-side lease handoffs ride the
    NIC (loopback serializes on the card) where flat alock's stay on the
    CPU. Against mcs the ALock-family advantage *widens* as locality
    deepens (the hlock_vs_mcs ratio rows); against flat alock the merged
    cohort is a measured cost that shrinks with locality (hlock_vs_alock
    rises toward 1.0) — both trade-offs stated as ratio rows.
    """
    exp = Experiment("rack-locality", n_seeds=n_seeds, n_events=n_events,
                     options=options)
    for w in _rack_locality_workloads():
        loc = w.locality if isinstance(w.locality, float) else w.locality[0]
        exp.add(w, label=f"{w.alg}.loc{int(float(loc) * 100)}")
    res = exp.run()
    rows = _rows(res)
    for loc in _RACK_LOCS:
        tag = int(loc * 100)
        hl = res[f"hlock.loc{tag}"].mean_mops
        for ref in ("alock", "mcs"):
            hit = hl / max(res[f"{ref}.loc{tag}"].mean_mops, 1e-9)
            rows.append({"name": f"loc{tag}.hlock_vs_{ref}_ratio",
                         "us_per_call": 0.0, "derived": f"{hit:.3f}x",
                         "ratio": hit, "locality": loc})
    return rows


def fig5_workloads() -> list[Workload]:
    """The Fig.5-shaped perf grid (shared by perfcheck and `paper-fig5`)."""
    return [Workload(alg, n_nodes=10, threads_per_node=8, n_locks=100,
                     locality=loc)
            for alg in ("alock", "spinlock", "mcs")
            for loc in (0.85, 0.95, 1.0)]


@scenario("paper-fig5",
          "the paper's Fig.5 throughput grid (perfcheck's measuring stick)",
          workloads=fig5_workloads)
def _paper_fig5(n_seeds, n_events, options):
    exp = Experiment("paper-fig5", n_seeds=n_seeds, n_events=n_events,
                     options=options)
    for w in fig5_workloads():
        exp.add(w, label=f"{w.alg}.loc{int(float(w.locality[0]) * 100)}"
                if isinstance(w.locality, tuple)
                else f"{w.alg}.loc{int(w.locality * 100)}")
    return _rows(exp.run())


@scenario("coord-stress",
          "threaded coordination plane under churn + lease-expiry storms")
def _coord_stress(n_seeds, n_events, options):
    from repro.coord.stress import ManualClock, run_coord_stress
    churn = (Phase(frac=0.3), Phase(frac=0.4, down_nodes=(2,),
                                    zipf_s=2.0),
             Phase(frac=0.3))
    rows = []
    ops_per_thread = max(20, min(n_events // 100, 300))
    for seed in range(n_seeds):
        w = Workload("alock", n_nodes=3, threads_per_node=4, n_locks=12,
                     locality=0.9, seed=seed, phases=churn)
        rep = run_coord_stress(w, ops_per_thread=ops_per_thread,
                               clock=ManualClock())
        rows.append({
            "name": f"coord.churn.seed{seed}", "us_per_call": 0.0,
            "derived": (f"ops={rep.ops},local={rep.local_ops},"
                        f"remote={rep.remote_ops},"
                        f"steals={rep.lease_steals}"),
            "ops": rep.ops, "local_ops": rep.local_ops,
            "remote_ops": rep.remote_ops,
            "lease_grants": rep.lease_grants,
            "lease_steals": rep.lease_steals,
            "phase_members": rep.phase_members,
        })
    return rows

"""Execution options as an explicit immutable object.

``ExecOptions`` carries everything about *how* a sweep executes — backend,
device sharding, chunking — as one frozen value that callers thread
explicitly through the benchmark suite and ``Experiment.run``. It replaces
the old mutable ``benchmarks/common.py::EXEC`` module global, whose state
leaked between test runs and benchmark sections.
"""
from __future__ import annotations

import os
from dataclasses import dataclass

_BACKENDS = ("auto", "xla", "pallas")


@dataclass(frozen=True)
class ExecOptions:
    """How to execute a sweep: (backend, devices, chunk), immutably.

    backend: "auto" | "xla" | "pallas" — per-replica engine
      (``sim.resolve_backend`` semantics).
    devices: shard sweep buckets over the first N JAX devices (mesh axis
      "data"); None keeps the single-dispatch layout.
    chunk: rows per device per dispatch (fixed-size chunks pin the
      executable shape; see ``core/batch.py``).
    """
    backend: str = "auto"
    devices: int | None = None
    chunk: int | None = None

    def __post_init__(self):
        if self.backend not in _BACKENDS:
            raise ValueError(f"backend must be one of {_BACKENDS}, got "
                             f"{self.backend!r}")
        for name in ("devices", "chunk"):
            v = getattr(self, name)
            if v is not None:
                v = int(v)
                if v < 1:
                    raise ValueError(f"{name} must be >= 1, got {v}")
                object.__setattr__(self, name, v)

    @classmethod
    def from_env(cls, **kw) -> "ExecOptions":
        """Defaults with ``REPRO_BACKEND`` honored; non-None kwargs
        override (an explicit ``backend=None`` means "not given on the
        CLI", so the env var still applies)."""
        kw = {k: v for k, v in kw.items() if v is not None}
        kw.setdefault("backend", os.environ.get("REPRO_BACKEND", "auto"))
        return cls(**kw)

    def device_list(self):
        """The resolved device list for ``batch.sweep(devices=)``."""
        if self.devices is None:
            return None
        import jax
        devs = jax.devices()
        if self.devices > len(devs):
            raise ValueError(f"devices={self.devices} but only {len(devs)} "
                             f"JAX device(s) are visible")
        return devs[:self.devices]

    def sweep_kwargs(self) -> dict:
        """Keyword arguments for ``repro.core.batch.sweep``."""
        return {"backend": self.backend, "devices": self.device_list(),
                "chunk": self.chunk}

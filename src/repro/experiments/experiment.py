"""The :class:`Experiment` builder: labeled workload grids x seeds x
:class:`~repro.experiments.options.ExecOptions`, run as one batched sweep.

An Experiment is the declarative counterpart of a hand-rolled config list:
you ``add`` workloads (or ``add_grid`` a cartesian product of spec-field
axes), then ``run()`` lowers everything through ``repro.core.batch.sweep``
— duplicates deduped, one compile per shape bucket, per-seed error bars —
and returns an :class:`ExperimentResult` addressable by label or spec.
"""
from __future__ import annotations

import itertools

from repro.core.batch import BatchResult, sweep
from repro.core.cost_model import CostModel
from repro.experiments.options import ExecOptions
from repro.workloads import Workload, as_workload


def _fmt_axis(name: str, value) -> str:
    if isinstance(value, str):          # e.g. alg="alock" -> "alock"
        return value
    if isinstance(value, float):
        return f"{name}{value:g}"
    if isinstance(value, (tuple, list)):
        return f"{name}{'x'.join(str(v) for v in value)}"
    return f"{name}{value}"


class Experiment:
    def __init__(self, name: str = "", *, n_seeds: int = 1,
                 n_events: int = 400_000, cm: CostModel = CostModel(),
                 options: ExecOptions = ExecOptions()):
        if n_seeds < 1:
            raise ValueError(f"n_seeds must be >= 1, got {n_seeds}")
        self.name = name
        self.n_seeds = n_seeds
        self.n_events = n_events
        self.cm = cm
        self.options = options
        self._entries: list[tuple[str, Workload]] = []
        self._labels: set[str] = set()

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def workloads(self) -> list[Workload]:
        return [w for _, w in self._entries]

    def add(self, workload, label: str | None = None) -> "Experiment":
        """Add one workload (SimConfig rides the adapter). Chainable."""
        w = as_workload(workload)
        if label is None:
            label = f"{w.alg}.{len(self._entries)}"
        if label in self._labels:
            raise ValueError(f"duplicate label {label!r}")
        self._labels.add(label)
        self._entries.append((label, w))
        return self

    def add_grid(self, base: Workload, prefix: str = "",
                 **axes) -> "Experiment":
        """Cartesian product over spec fields, e.g.
        ``add_grid(base, alg=("alock", "mcs"), locality=(0.85, 1.0))``.
        Labels are ``prefix + axis-value`` segments joined with ``.``."""
        names = list(axes)
        for combo in itertools.product(*(axes[n] for n in names)):
            w = base.replace(**dict(zip(names, combo)))
            seg = ".".join(_fmt_axis(n, v) for n, v in zip(names, combo))
            self.add(w, label=f"{prefix}{seg}" if prefix else seg)
        return self

    def run(self) -> "ExperimentResult":
        """One deduped batched sweep over every entry."""
        uniq = list(dict.fromkeys(w for _, w in self._entries))
        res = dict(zip(uniq, sweep(
            uniq, n_seeds=self.n_seeds, n_events=self.n_events, cm=self.cm,
            **self.options.sweep_kwargs())))
        return ExperimentResult(
            [(lbl, w, res[w]) for lbl, w in self._entries])


class ExperimentResult:
    """Results addressable by label (str) or by the Workload spec itself."""

    def __init__(self, rows: list[tuple[str, Workload, BatchResult]]):
        self._rows = rows
        self._by_label = {lbl: br for lbl, _, br in rows}
        self._by_workload = {w: br for _, w, br in rows}

    def __iter__(self):
        return iter(self._rows)

    def __len__(self) -> int:
        return len(self._rows)

    @property
    def labels(self) -> list[str]:
        return [lbl for lbl, _, _ in self._rows]

    def __getitem__(self, key) -> BatchResult:
        if isinstance(key, str):
            return self._by_label[key]
        return self._by_workload[as_workload(key)]

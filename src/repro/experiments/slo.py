"""Latency/throughput SLOs for scenario programs.

An :class:`Slo` is a small declarative contract a registered scenario can
carry (``@scenario(..., slo=Slo(...))``): simulated p99 acquire->release
latency must stay under ``p99_ns``, and the harness must sustain at least
``min_events_per_sec`` simulated events per wall-clock second.
:func:`check_slo` evaluates a contract against the scenario's result rows
and returns an :class:`SloReport`; ``benchmarks.run --check-slo`` turns
the report into a process exit code, which is what gates the CI scenarios
leg.

The two bounds deliberately live on different clocks:

  * ``p99_ns`` reads the *simulated* latency pool (deterministic for a
    fixed spec + seed set — a tightened bound fails reproducibly, which
    the exit-code tests rely on);
  * ``min_events_per_sec`` reads the harness's *wall-clock* event rate
    (the perf trajectory perfcheck records) — registered scenarios keep
    this floor loose enough for CI smoke runs and let perfcheck carry the
    fine-grained trajectory.

Beyond the scenario-wide bounds, ``per_label`` attaches a *sub-contract*
to individual result rows by name: ``Slo(per_label={"alock-rw.rf99":
Slo(p99_ns=2e5)})`` gates only the row named ``alock-rw.rf99``, with its
own (usually tighter) bounds. A per-label entry whose row never appears
is a violation, exactly like a scenario-wide bound matching nothing —
renaming a workload label cannot silently un-gate it.

>>> from repro.experiments.slo import Slo, check_slo
>>> slo = Slo(p99_ns=5e6, min_events_per_sec=1.0)
>>> rows = [{"name": "a", "p99_lat_ns": 4e6},
...         {"name": "w", "events_per_sec": 20.0}]
>>> check_slo(slo, rows).ok
True
>>> rep = check_slo(Slo(p99_ns=1.0), rows)
>>> rep.ok, len(rep.violations)
(False, 1)
>>> tiered = Slo(p99_ns=5e6, per_label={"a": Slo(p99_ns=4.5e6)})
>>> check_slo(tiered, rows).ok
True
>>> rep = check_slo(Slo(p99_ns=5e6,
...                     per_label={"a": Slo(p99_ns=1e6),
...                                "gone": Slo(p99_ns=1e6)}), rows)
>>> rep.ok, len(rep.violations)
(False, 2)
"""
from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class Slo:
    """A scenario-level service objective (either bound may be None).

    p99_ns: ceiling on simulated p99 acquire->release latency, in ns,
      checked against every result row carrying a ``p99_lat_ns`` key.
    min_events_per_sec: floor on the harness's wall-clock simulated-event
      rate, checked against every row carrying an ``events_per_sec`` key
      (the per-scenario summary row ``benchmarks.run`` appends).
    """
    p99_ns: float | None = None
    min_events_per_sec: float | None = None
    #: per-row sub-contracts: ``{row name: Slo}`` (or a pair tuple) —
    #: each applies its own bounds to exactly the row of that name, on
    #: top of the scenario-wide bounds above. One level only.
    per_label: object = ()

    def __post_init__(self):
        for name in ("p99_ns", "min_events_per_sec"):
            v = getattr(self, name)
            if v is None:
                continue
            v = float(v)
            if not math.isfinite(v) or v <= 0.0:
                raise ValueError(
                    f"Slo.{name} must be finite and > 0, got {v}")
            object.__setattr__(self, name, v)
        pl = self.per_label
        pl = tuple(sorted(pl.items())) if isinstance(pl, dict) \
            else tuple(tuple(p) for p in pl)
        for label, sub in pl:
            if not isinstance(sub, Slo):
                raise TypeError(f"per_label[{label!r}] must be an Slo, "
                                f"got {type(sub).__name__}")
            if sub.per_label:
                raise ValueError(f"per_label[{label!r}] may not nest its "
                                 f"own per_label bounds")
        object.__setattr__(self, "per_label", pl)
        if self.p99_ns is None and self.min_events_per_sec is None \
                and not pl:
            raise ValueError("an Slo needs at least one bound")


@dataclass(frozen=True)
class SloReport:
    """The outcome of one :func:`check_slo` evaluation."""
    slo: Slo
    checked: int                    # rows any bound applied to
    violations: tuple = ()          # human-readable, one per failing row

    @property
    def ok(self) -> bool:
        return not self.violations


def check_slo(slo: Slo, rows) -> SloReport:
    """Evaluate ``slo`` against scenario result rows.

    Rows are the dicts a registry scenario returns (plus the summary row
    the benchmark runner appends). A row participates in a bound iff it
    carries that bound's key — rows without latency/rate keys (ratio
    rows, coord-plane rows) pass through unexamined. A bound that
    matched *no* row at all is itself a violation: an SLO that silently
    checks nothing would gate nothing. ``per_label`` sub-contracts are
    evaluated against exactly the rows bearing their name, with the same
    matched-nothing rule per entry.
    """
    violations = []
    checked = 0
    matched = {"p99_ns": False, "min_events_per_sec": False}
    for r in rows:
        name = r.get("name", "?")
        if slo.p99_ns is not None and "p99_lat_ns" in r:
            matched["p99_ns"] = True
            checked += 1
            p99 = float(r["p99_lat_ns"])
            if not (p99 <= slo.p99_ns):        # NaN (no samples) fails too
                violations.append(
                    f"{name}: p99 latency {p99:.0f}ns exceeds SLO "
                    f"{slo.p99_ns:.0f}ns")
        if slo.min_events_per_sec is not None and "events_per_sec" in r:
            matched["min_events_per_sec"] = True
            checked += 1
            eps = float(r["events_per_sec"])
            if not (eps >= slo.min_events_per_sec):
                violations.append(
                    f"{name}: {eps:.1f} events/sec under SLO floor "
                    f"{slo.min_events_per_sec:.1f}")
    for bound, hit in matched.items():
        if getattr(slo, bound) is not None and not hit:
            violations.append(
                f"slo bound {bound} matched no result row — nothing was "
                f"checked")
    for label, sub in slo.per_label:
        sub_rows = [r for r in rows if r.get("name") == label]
        rep = check_slo(sub, sub_rows)
        checked += rep.checked
        violations.extend(f"[{label}] {v}" for v in rep.violations)
    return SloReport(slo=slo, checked=checked, violations=tuple(violations))

"""Experiment composition: workload grids x seeds x execution options.

>>> from repro.experiments import Experiment, ExecOptions
>>> from repro.workloads import Workload
>>> exp = (Experiment("demo", n_seeds=5, n_events=50_000,
...                   options=ExecOptions(backend="xla"))
...        .add_grid(Workload("alock", 4, 4, 16),
...                  alg=("alock", "mcs"), locality=(0.85, 1.0)))
>>> res = exp.run()
>>> res["alock.locality0.85"].mean_mops      # doctest: +SKIP

Named scenario programs live in the registry (``run_scenario`` /
``scenario_names``) — the single entry point behind
``benchmarks.run --scenario`` and ``benchmarks/perfcheck.py``.
"""
from repro.experiments.experiment import Experiment, ExperimentResult
from repro.experiments.options import ExecOptions
from repro.experiments.registry import (Scenario, fig5_workloads,
                                        get_scenario, run_scenario,
                                        scenario, scenario_names)

__all__ = [
    "ExecOptions", "Experiment", "ExperimentResult", "Scenario",
    "fig5_workloads", "get_scenario", "run_scenario", "scenario",
    "scenario_names",
]

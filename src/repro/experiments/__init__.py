"""Experiment composition: workload grids x seeds x execution options.

An :class:`Experiment` collects labeled ``repro.workloads.Workload`` specs
(or whole cartesian grids of them), then runs everything as one deduped
batched sweep — one compile per ``(alg, T, N, K, n_events)`` shape bucket,
per-seed error bars, results addressable by label or by spec:

>>> from repro.experiments import Experiment, ExecOptions
>>> from repro.workloads import Workload
>>> exp = (Experiment("demo", n_seeds=2, n_events=1500,
...                   options=ExecOptions(backend="xla"))
...        .add_grid(Workload("alock", 2, 2, 8), locality=(0.85, 1.0)))
>>> res = exp.run()
>>> res.labels
['locality0.85', 'locality1']
>>> res["locality1"].mean_mops >= res["locality0.85"].mean_mops
True

``ExecOptions`` is the immutable how-to-execute value (backend, device
sharding, chunking) threaded explicitly through the benchmark suite —
there is no process-wide execution state.

Named scenario programs live in the registry (``run_scenario`` /
``scenario_names``) — the single entry point behind
``benchmarks.run --scenario`` and ``benchmarks/perfcheck.py``. A scenario
can carry an :class:`Slo` (simulated-p99 ceiling, wall-clock events/sec
floor); ``benchmarks.run --check-slo`` evaluates it with
:func:`check_slo` and gates CI on the result.
"""
from repro.experiments.experiment import Experiment, ExperimentResult
from repro.experiments.options import ExecOptions
from repro.experiments.registry import (Scenario, fig5_workloads,
                                        get_scenario, run_scenario,
                                        scenario, scenario_names,
                                        scenario_workloads)
from repro.experiments.slo import Slo, SloReport, check_slo

__all__ = [
    "ExecOptions", "Experiment", "ExperimentResult", "Scenario", "Slo",
    "SloReport", "check_slo", "fig5_workloads", "get_scenario",
    "run_scenario", "scenario", "scenario_names", "scenario_workloads",
]

"""Distributed lock table — the paper's evaluation application, usable as a
real (threaded) coordination substrate.

Nodes are emulated in-process; the operation-asymmetric memory contract is
preserved: lock words (tails, victim) are mutated under a per-cell "hardware"
mutex that stands in for cache-coherent CAS / RNIC-serialized rCAS, while
descriptor fields (budget, next) are plain single-writer fields, exactly as
the algorithm requires (a thread spins locally on its own descriptor; only
its predecessor writes it). An optional `net` hook injects per-operation
latency so integration tests can exercise realistic interleavings.

The framework's coordination plane (checkpoint leases, elastic membership —
repro.coord) runs on this table.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable

LOCAL, REMOTE = 0, 1


class Descriptor:
    __slots__ = ("budget", "next", "_cohort", "_cell")

    def __init__(self):
        self.budget = -1
        self.next = None


class ALockCell:
    """One 64B ALock: two cohort tails + victim."""
    __slots__ = ("hw", "tail", "victim")

    def __init__(self):
        self.hw = threading.Lock()
        self.tail = [None, None]
        self.victim = 0


@dataclass
class TableStats:
    ops: int = 0
    remote_ops: int = 0
    local_ops: int = 0
    reacquires: int = 0
    _lock: threading.Lock = field(default_factory=threading.Lock)

    def bump(self, **kw):
        with self._lock:
            for k, v in kw.items():
                setattr(self, k, getattr(self, k) + v)


class LockTable:
    def __init__(self, n_nodes: int, locks_per_node: int,
                 local_budget: int = 5, remote_budget: int = 20,
                 net: Callable[[str, int], None] | None = None):
        self.n_nodes = n_nodes
        self.locks_per_node = locks_per_node
        self.b_init = (local_budget, remote_budget)
        self.cells = [ALockCell() for _ in range(n_nodes * locks_per_node)]
        self.net = net
        self.stats = TableStats()

    # -- helpers ----------------------------------------------------------
    def owner_node(self, lock_id: int) -> int:
        return lock_id // self.locks_per_node

    def _op(self, kind: str, cohort: int):
        if cohort == REMOTE:
            self.stats.bump(remote_ops=1)
            if self.net:
                self.net(kind, cohort)
        else:
            self.stats.bump(local_ops=1)

    @staticmethod
    def _pause():
        time.sleep(0)  # yield GIL; local spin

    # -- paper API: Lock / Unlock ------------------------------------------
    def lock(self, node_id: int, lock_id: int) -> Descriptor:
        cell = self.cells[lock_id]
        c = LOCAL if self.owner_node(lock_id) == node_id else REMOTE
        d = Descriptor()
        with cell.hw:                      # rCAS-retry swap, linearized
            prev = cell.tail[c]
            cell.tail[c] = d
        self._op("swap", c)
        if prev is None:
            d.budget = self.b_init[c]
            self._peterson(cell, c)
        else:
            prev.next = d
            self._op("write_next", c)
            while d.budget == -1:          # local spin on own descriptor
                self._pause()
            if d.budget == 0:
                self.stats.bump(reacquires=1)
                self._peterson(cell, c)
                d.budget = self.b_init[c]
        d._cohort = c  # type: ignore[attr-defined]
        d._cell = cell  # type: ignore[attr-defined]
        return d

    def _peterson(self, cell: ALockCell, c: int):
        cell.victim = c
        self._op("set_victim", c)
        while True:
            # one 64B read observes both tails + victim
            other_locked = cell.tail[1 - c] is not None
            vict = cell.victim
            self._op("pet_check", c)
            if not other_locked or vict != c:
                return
            self._pause()

    def unlock(self, d: Descriptor):
        cell, c = d._cell, d._cohort  # type: ignore[attr-defined]
        with cell.hw:
            solo = cell.tail[c] is d
            if solo:
                cell.tail[c] = None
        self._op("rel_cas", c)
        if not solo:
            while d.next is None:
                self._pause()
            d.next.budget = d.budget - 1
            self._op("pass", c)
        self.stats.bump(ops=1)

    # -- convenience -------------------------------------------------------
    def critical(self, node_id: int, lock_id: int):
        table = self

        class _Guard:
            def __enter__(self):
                self.d = table.lock(node_id, lock_id)
                return self.d

            def __exit__(self, *exc):
                table.unlock(self.d)
                return False

        return _Guard()

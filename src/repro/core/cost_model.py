"""RDMA cluster cost model (calibrated to the paper's CloudLab/CX3 setup).

Three effects drive the paper's results and are modeled explicitly:
  1. operation asymmetry — shared-memory ops ~100ns vs one-sided RDMA ~1.5us;
  2. RNIC serialization + loopback PCIe pressure — every RDMA op occupies the
     target card for `rnic_svc_ns`; loopback traffic additionally inflates
     service linearly in the number of co-located loopback-active threads
     past a knee (Fig. 1's rise-then-collapse);
  3. QP-context thrashing — past ~450 cached QPs (StaR), service inflates.

All factors that depend only on the configuration (thread/node counts,
algorithm) are precomputed to integer-ns scalars — the 8 *cost rows* of
:meth:`CostModel.cost_rows` — so the JAX event loop stays branch-light.

Named profiles
--------------
A :class:`CostProfile` is a :class:`CostModel` with a name, registered in
:data:`COST_PROFILES`. Profiles let a ``repro.workloads.Workload`` (or a
single :class:`~repro.workloads.Phase` of one) swap the whole ns table —
e.g. a mid-run NIC-congestion burst — while the table stays a *traced
operand* of the engines, so mixing profiles never adds a compile:

>>> from repro.core.cost_model import COST_PROFILES, CostProfile
>>> sorted(COST_PROFILES)
['congested-nic', 'default', 'idle-nic']
>>> COST_PROFILES["default"].cost_rows("alock", 2, 2)
(100, 400, 250, 300, 250, 250, 1500, 1800)
>>> c = COST_PROFILES["congested-nic"]
>>> c.rnic_svc_ns > CostProfile().rnic_svc_ns
True

``resolve_cost`` is the single coercion point the workload layer uses:
``None`` (inherit), a profile name, an explicit model, or a field-override
mapping all resolve to a concrete :class:`CostModel`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

# Width of the cost_rows() table the engines consume. Row order: (local,
# poll, cs, think, svc_remote, svc_loopback, wire_remote, wire_loopback).
# Index 3 (think) is carried for layout parity with the legacy topology()
# tuple; the engines take think time from the separate per-phase
# ``think_ns`` operand (which folds in the spec's think multiplier).
N_COST_ROWS = 8


@dataclass(frozen=True)
class CostModel:
    # Constant provenance: the defaults reproduce the paper's testbed
    # (CloudLab r320/c6220 nodes, ConnectX-3 RNICs; evaluation setup in
    # §5, mechanisms in §2). Per-constant anchors:
    local_ns: float = 100.0        # shared-memory op (§2: ALock's local
    #   cohort never touches the RNIC; ~100ns cache-coherent CAS/read)
    spin_poll_ns: float = 400.0    # local spin re-check interval (§3:
    #   descriptor polling cadence of the embedded MCS queues)
    remote_wire_ns: float = 1500.0  # one-sided RDMA wire+DMA latency
    #   (§5/Fig. 6: ~1.5us median one-sided verb on CX3)
    loopback_wire_ns: float = 1800.0  # loopback: PCIe down+up through the
    #   card (§2: loopback pays the PCIe round trip twice; > remote wire)
    rnic_svc_ns: float = 250.0     # per-op card occupancy (§5: CX3
    #   saturates at ~3-4 Mops/s of one-sided ops => ~250-330ns/op)
    cs_ns: float = 250.0           # critical-section body (§5 workload:
    #   short CS touching a few cached lines)
    think_ns: float = 300.0        # app work between lock ops (§5
    #   workload generator's inter-op gap)
    pcie_knee: int = 2             # threads of loopback traffic a card
    #   absorbs before RX-buffer/PCIe pressure shows (Fig. 1's knee)
    pcie_beta: float = 0.8         # loopback service inflation per extra
    #   thread past the knee (Fig. 1's collapse slope)
    qp_cache: int = 450            # QPC cache capacity (StaR; §2 cites
    #   QP-context thrashing past ~450 cached QPs)
    qp_alpha: float = 1.2          # service inflation slope past the cache
    thrash_cap: float = 5.0        # inflation ceiling (thrashed service
    #   plateaus rather than diverging)

    def qp_count(self, n_nodes: int, threads_per_node: int,
                 uses_loopback: bool) -> int:
        """QPs a single card must track. ALock drops the loopback share
        (~1/n of the system's QPs, §2 of the paper)."""
        t, n = threads_per_node, n_nodes
        inbound = (n - 1) * t
        outbound = t * max(n - 1, 0)
        loop = t if uses_loopback else 0
        return inbound + outbound + 2 * loop

    def thrash_factor(self, n_nodes: int, threads_per_node: int,
                      uses_loopback: bool) -> float:
        qps = self.qp_count(n_nodes, threads_per_node, uses_loopback)
        if qps <= self.qp_cache:
            return 1.0
        return min(1.0 + self.qp_alpha * (qps / self.qp_cache - 1.0),
                   self.thrash_cap)

    def loopback_factor(self, threads_per_node: int,
                        uses_loopback: bool) -> float:
        """PCIe/RX-buffer pressure from loopback traffic (Fig. 1)."""
        if not uses_loopback:
            return 1.0
        extra = max(0, threads_per_node - self.pcie_knee)
        return 1.0 + self.pcie_beta * extra

    def svc_ns(self, n_nodes: int, threads_per_node: int,
               uses_loopback: bool, is_loopback_op: bool) -> float:
        f = self.thrash_factor(n_nodes, threads_per_node, uses_loopback)
        if is_loopback_op:
            f *= self.loopback_factor(threads_per_node, uses_loopback)
        return self.rnic_svc_ns * f

    def cost_rows(self, alg: str, n_nodes: int,
                  threads_per_node: int) -> tuple[int, ...]:
        """The 8 integer-ns cost rows the event loop consumes, in operand
        order: ``(local, poll, cs, think, svc_remote, svc_loopback,
        wire_remote, wire_loopback)``.

        This is the single source of the row arithmetic — ``sim.topology``
        and the workload lowering both call it, which is what keeps a
        default-profile :class:`~repro.workloads.Workload` bitwise-equal
        to the pre-profile engine (asserted in tests).
        """
        uses_loopback = alg != "alock"
        return tuple(int(round(v)) for v in (
            self.local_ns, self.spin_poll_ns, self.cs_ns, self.think_ns,
            self.svc_ns(n_nodes, threads_per_node, uses_loopback, False),
            self.svc_ns(n_nodes, threads_per_node, uses_loopback, True),
            self.remote_wire_ns, self.loopback_wire_ns,
        ))


@dataclass(frozen=True)
class CostProfile(CostModel):
    """A named :class:`CostModel` ns table (frozen, hashable — rides
    inside ``Workload``/``Phase`` specs as the ``cost`` field)."""
    name: str = "default"


# Named profiles for phase programs. "default" must stay field-for-field
# identical to CostModel() — the bitwise contract of every pre-profile
# workload rests on it (tests assert the rows match).
COST_PROFILES: dict[str, CostProfile] = {
    "default": CostProfile(),
    # An unloaded fabric: the card is below its serialization point and
    # the wire is quiet — service/wire at the low end of the paper's §5
    # microbenchmark range.
    "idle-nic": CostProfile(
        name="idle-nic", rnic_svc_ns=150.0, remote_wire_ns=1200.0,
        loopback_wire_ns=1500.0),
    # A congested fabric: card occupancy past the CX3 saturation point
    # and inflated wire/PCIe latencies — the regime of Fig. 1's collapse
    # and the §5 high-contention tails. Loopback designs hurt doubly
    # (steeper pcie_beta); ALock's local cohort is immune by §2's
    # construction (no RNIC on the local path).
    "congested-nic": CostProfile(
        name="congested-nic", rnic_svc_ns=900.0, remote_wire_ns=3500.0,
        loopback_wire_ns=5200.0, pcie_beta=1.6, qp_alpha=1.8),
}

_FIELD_NAMES = tuple(f.name for f in dataclasses.fields(CostModel))


def resolve_cost(cost, base: CostModel) -> CostModel:
    """Coerce a spec-level ``cost`` value to a concrete :class:`CostModel`.

    Accepted forms (the canonical frozen forms stored by
    ``repro.workloads``): ``None`` -> ``base`` unchanged; a profile name
    from :data:`COST_PROFILES`; a ``CostModel``/``CostProfile`` instance;
    or a tuple of ``(field, value)`` override pairs applied on top of
    ``base`` (the frozen form of a ``{"rnic_svc_ns": 900.0}``-style dict).
    """
    if cost is None:
        return base
    if isinstance(cost, str):
        try:
            return COST_PROFILES[cost]
        except KeyError:
            raise ValueError(
                f"unknown cost profile {cost!r}; registered: "
                f"{sorted(COST_PROFILES)}") from None
    if isinstance(cost, CostModel):
        return cost
    if isinstance(cost, tuple):
        return dataclasses.replace(base, **dict(cost))
    raise TypeError(f"cost must be None, a profile name, a CostModel or "
                    f"field overrides, got {type(cost)!r}")


def freeze_cost(cost):
    """Validate + canonicalize a user-facing ``cost`` value to the frozen,
    hashable form ``resolve_cost`` accepts. Mappings become sorted
    ``(field, float)`` tuples; unknown field names are rejected here, at
    spec-construction time, not at lowering time."""
    if cost is None or isinstance(cost, CostModel):
        return cost
    if isinstance(cost, str):
        if cost not in COST_PROFILES:
            raise ValueError(f"unknown cost profile {cost!r}; registered: "
                             f"{sorted(COST_PROFILES)}")
        return cost
    if isinstance(cost, dict):
        cost = tuple(sorted(cost.items()))
    if isinstance(cost, tuple):
        bad = [k for k, _ in cost if k not in _FIELD_NAMES]
        if bad:
            raise ValueError(f"unknown cost-model field(s) {bad}; pick "
                             f"from {_FIELD_NAMES}")
        return tuple((str(k), float(v)) for k, v in cost)
    raise TypeError(f"cost must be None, a profile name, a CostModel, or "
                    f"a field-override mapping, got {type(cost)!r}")

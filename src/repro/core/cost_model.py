"""RDMA cluster cost model (calibrated to the paper's CloudLab/CX3 setup).

Three effects drive the paper's results and are modeled explicitly:
  1. operation asymmetry — shared-memory ops ~100ns vs one-sided RDMA ~1.5us;
  2. RNIC serialization + loopback PCIe pressure — every RDMA op occupies the
     target card for `rnic_svc_ns`; loopback traffic additionally inflates
     service linearly in the number of co-located loopback-active threads
     past a knee (Fig. 1's rise-then-collapse);
  3. QP-context thrashing — past ~450 cached QPs (StaR), service inflates.

All factors that depend only on the configuration (thread/node counts,
algorithm) are precomputed to scalars so the JAX event loop stays branch-
light.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class CostModel:
    local_ns: float = 100.0        # shared-memory op
    spin_poll_ns: float = 400.0    # local spin re-check interval
    remote_wire_ns: float = 1500.0  # one-sided RDMA wire+DMA latency
    loopback_wire_ns: float = 1800.0  # loopback: PCIe down+up through the card
    rnic_svc_ns: float = 250.0     # per-op card occupancy (CX3 ~3-4 Mops/s)
    cs_ns: float = 250.0           # critical-section body
    think_ns: float = 300.0        # app work between lock ops
    pcie_knee: int = 2             # threads of loopback traffic a card absorbs
    pcie_beta: float = 0.8         # loopback service inflation per extra thread
    qp_cache: int = 450            # QPC cache capacity (StaR)
    qp_alpha: float = 1.2          # service inflation slope past the cache
    thrash_cap: float = 5.0

    def qp_count(self, n_nodes: int, threads_per_node: int,
                 uses_loopback: bool) -> int:
        """QPs a single card must track. ALock drops the loopback share
        (~1/n of the system's QPs, §2 of the paper)."""
        t, n = threads_per_node, n_nodes
        inbound = (n - 1) * t
        outbound = t * max(n - 1, 0)
        loop = t if uses_loopback else 0
        return inbound + outbound + 2 * loop

    def thrash_factor(self, n_nodes: int, threads_per_node: int,
                      uses_loopback: bool) -> float:
        qps = self.qp_count(n_nodes, threads_per_node, uses_loopback)
        if qps <= self.qp_cache:
            return 1.0
        return min(1.0 + self.qp_alpha * (qps / self.qp_cache - 1.0),
                   self.thrash_cap)

    def loopback_factor(self, threads_per_node: int,
                        uses_loopback: bool) -> float:
        """PCIe/RX-buffer pressure from loopback traffic (Fig. 1)."""
        if not uses_loopback:
            return 1.0
        extra = max(0, threads_per_node - self.pcie_knee)
        return 1.0 + self.pcie_beta * extra

    def svc_ns(self, n_nodes: int, threads_per_node: int,
               uses_loopback: bool, is_loopback_op: bool) -> float:
        f = self.thrash_factor(n_nodes, threads_per_node, uses_loopback)
        if is_loopback_op:
            f *= self.loopback_factor(threads_per_node, uses_loopback)
        return self.rnic_svc_ns * f

"""Batched simulation engine: vmap-over-(config x seed) on top of sim.py.

The paper's headline figures (Fig. 5/6) are grids of simulator runs. Running
each ``(alg, nodes, tpn, locks, locality, seed)`` point as its own
``simulate()`` call costs one device dispatch per point and gives a single
seed with no error bars. This module batches instead:

  * ``_run_events_batch`` vmaps the serial event loop over a flattened
    (config x seed) axis, so one compile + one dispatch yields S independent
    replicas for every config that shares a shape;
  * ``sweep`` buckets an arbitrary config list by the static shape key
    ``(alg, T, N, K, n_events)`` — everything else (locality, budgets, cost
    scalars, seeds) rides along as *batched traced operands*, so each bucket
    compiles exactly once no matter how many configs/seeds it carries;
  * ``BatchResult`` keeps the per-seed samples bitwise-identical to
    individual ``simulate()`` calls (tested) and derives mean/ci95/p50/p99
    aggregates from them.

This is the foundation for multi-device scaling: a bucket's flattened batch
axis is exactly the axis a later PR shards with pmap/shard_map.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core.cost_model import CostModel
from repro.core.sim import (I32, LAT_SAMPLES, SimConfig, SimResult,
                            _run_events, topology)

_N_COSTS = 8


def shape_key(cfg: SimConfig, n_events: int):
    """The static-argument tuple that determines a compile: two configs with
    equal keys can share one XLA executable."""
    return (cfg.alg, cfg.n_nodes * cfg.threads_per_node, cfg.n_nodes,
            cfg.n_locks, n_events)


@functools.partial(jax.jit,
                   static_argnames=("alg", "T", "N", "K", "n_events"))
def _run_events_batch(alg, T, N, K, n_events, locality, b_init, thread_node,
                      lock_node, costs, seed):
    """One shape bucket: every batched operand has leading axis B = C * S.

    thread_node/lock_node are functions of the shape key alone and stay
    unbatched (broadcast).
    """
    point = functools.partial(_run_events, alg, T, N, K, n_events)
    return jax.vmap(point, in_axes=(0, 0, None, None, 0, 0))(
        locality, b_init, thread_node, lock_node, costs, seed)


class BatchResult(NamedTuple):
    """Per-seed samples + aggregate statistics for one config.

    Sample arrays are stacked over the seed axis S; ``result(i)`` recovers
    the i-th seed as a plain ``SimResult`` (bitwise-equal to running
    ``simulate`` with that seed).
    """
    config: SimConfig
    n_events: int
    seeds: np.ndarray             # (S,)
    ops: np.ndarray               # (S,)
    sim_ns: np.ndarray            # (S,)
    throughput_mops: np.ndarray   # (S,)
    lat_ns: np.ndarray            # (S, LAT_SAMPLES), -1 padded
    per_thread_ops: np.ndarray    # (S, T)
    reacquires: np.ndarray        # (S,)
    passes: np.ndarray            # (S,)

    @property
    def n_seeds(self) -> int:
        return len(self.seeds)

    def result(self, i: int) -> SimResult:
        return SimResult(int(self.ops[i]), int(self.sim_ns[i]),
                         float(self.throughput_mops[i]), self.lat_ns[i],
                         self.per_thread_ops[i], int(self.reacquires[i]),
                         int(self.passes[i]))

    # -- throughput aggregates ---------------------------------------------

    @property
    def mean_mops(self) -> float:
        return float(self.throughput_mops.mean())

    @property
    def ci95_mops(self) -> float:
        """Half-width of the normal-approx 95% CI of the mean (0 for S=1)."""
        s = self.throughput_mops
        if len(s) < 2:
            return 0.0
        return float(1.96 * s.std(ddof=1) / np.sqrt(len(s)))

    # -- latency aggregates (valid samples only; -1 is padding) ------------

    def _lat_pool(self) -> np.ndarray:
        flat = self.lat_ns.ravel()
        return flat[flat >= 0]

    @property
    def mean_lat_us(self) -> float:
        pool = self._lat_pool()
        return float(pool.mean()) / 1e3 if len(pool) else float("nan")

    @property
    def p50_lat_ns(self) -> float:
        pool = self._lat_pool()
        return float(np.percentile(pool, 50)) if len(pool) else float("nan")

    @property
    def p99_lat_ns(self) -> float:
        pool = self._lat_pool()
        return float(np.percentile(pool, 99)) if len(pool) else float("nan")

    def lat_pct(self, q: float) -> tuple[float, float]:
        """(mean, ci95) of the q-th latency percentile across seeds."""
        per_seed = []
        for row in self.lat_ns:
            valid = row[row >= 0]
            if len(valid):
                per_seed.append(np.percentile(valid, q))
        if not per_seed:
            return float("nan"), 0.0
        per_seed = np.asarray(per_seed, np.float64)
        mean = float(per_seed.mean())
        if len(per_seed) < 2:
            return mean, 0.0
        return mean, float(1.96 * per_seed.std(ddof=1)
                           / np.sqrt(len(per_seed)))


def sweep(configs: Sequence[SimConfig], n_seeds: int = 1,
          n_events: int = 400_000,
          cm: CostModel = CostModel()) -> list[BatchResult]:
    """Run every config with seeds ``cfg.seed + [0, n_seeds)``; one compile
    and one device dispatch per ``shape_key`` bucket.

    Returns BatchResults parallel to ``configs`` (duplicates are simulated
    twice — dedupe upstream if the grid overlaps).
    """
    if n_seeds < 1:
        raise ValueError(f"n_seeds must be >= 1, got {n_seeds}")
    configs = list(configs)
    buckets: dict[tuple, list[int]] = {}
    for i, cfg in enumerate(configs):
        buckets.setdefault(shape_key(cfg, n_events), []).append(i)

    out: list[BatchResult | None] = [None] * len(configs)
    for key, idxs in buckets.items():
        alg, T, N, K, _ = key
        thread_node, lock_node, costs = topology(alg, N, T // N, K, cm)
        C, S = len(idxs), n_seeds
        loc = np.empty((C, S), np.float32)
        b_init = np.empty((C, S, 2), np.int32)
        seeds = np.empty((C, S), np.int32)
        # constant within a bucket today, but kept a batched operand so a
        # later PR can vary the cost model per config without recompiling
        cost_rows = np.broadcast_to(
            np.asarray(costs, np.int32), (C, S, _N_COSTS)).copy()
        for row, i in enumerate(idxs):
            cfg = configs[i]
            loc[row] = cfg.locality
            b_init[row] = np.asarray(cfg.b_init, np.int32)
            seeds[row] = cfg.seed + np.arange(S, dtype=np.int32)

        def flat(a):
            return jnp.asarray(a.reshape((C * S,) + a.shape[2:]))

        with enable_x64():
            done, lat, _lat_n, t_end, nreacq, npass = _run_events_batch(
                alg, T, N, K, n_events, flat(loc), flat(b_init),
                thread_node, lock_node,
                tuple(flat(cost_rows[..., j]) for j in range(_N_COSTS)),
                flat(seeds))
        done = np.asarray(done).reshape(C, S, T)
        lat = np.asarray(lat).reshape(C, S, LAT_SAMPLES)
        t_end = np.asarray(t_end).reshape(C, S)
        nreacq = np.asarray(nreacq).reshape(C, S)
        npass = np.asarray(npass).reshape(C, S)

        for row, i in enumerate(idxs):
            ops = done[row].sum(axis=1).astype(np.int64)
            sim_ns = np.maximum(t_end[row].astype(np.int64), 1)
            # per-element arithmetic matches simulate()'s scalar formula
            # bitwise: ops / sim_ns * 1e3 in float64 either way
            mops = ops / sim_ns * 1e3
            out[i] = BatchResult(configs[i], n_events, seeds[row], ops,
                                 sim_ns, mops, lat[row], done[row],
                                 nreacq[row], npass[row])
    return out

"""Batched simulation engine: vmap-over-(workload x seed) on top of sim.py.

The paper's headline figures (Fig. 5/6) are grids of simulator runs. Running
each (workload, seed) point as its own ``simulate()`` call costs one device
dispatch per point and gives a single seed with no error bars. This module
batches instead:

  * ``_run_events_batch`` vmaps the serial event loop over a flattened
    (workload x seed) axis, so one compile + one dispatch yields S
    independent replicas for every workload that shares a shape;
  * ``sweep`` accepts ``repro.workloads.Workload`` specs (legacy
    ``SimConfig`` rides through the bitwise-faithful adapter), lowers each
    to its traced ``WorkloadOperands`` struct, and buckets by the static
    shape key ``(alg, T, N, K, n_events)`` — everything workload-shaped
    (per-thread locality, Zipf CDFs, phase programs, think times, active
    masks, per-phase ALock budgets, per-phase cost-model rows, seeds)
    rides along as *batched traced operands*. Replicas with fewer phases
    than their bucket's max are padded with unreachable phases
    (``pad_phases`` — provably inert, including the cost/budget rows), so
    a sweep mixing scenarios — even ones under different cost profiles or
    budget programs — still compiles exactly once per bucket;
  * ``BatchResult`` keeps the per-seed samples bitwise-identical to
    individual ``simulate()`` calls (tested) and derives mean/ci95/p50/p99
    aggregates from them.

Execution backends and sharding
-------------------------------
``sweep(..., backend=)`` picks the per-replica engine: the XLA ``fori_loop``
(``"xla"``, the correctness oracle) or the Pallas event-loop kernel
(``"pallas"``, ``repro.kernels.event_loop`` — VMEM-resident state, replicas
tiled across the Pallas grid). ``"auto"`` resolves per
``sim.resolve_backend``. Both produce bitwise-identical replicas.

``sweep(..., devices=, chunk=)`` turns on the sharded bucket layout: each
bucket's flattened (workload x seed) axis is measured in dispatch *units*
of ``chunk`` rows per device (``chunk * n_devices`` rows each), and the
unit count is greedily decomposed into power-of-two **superchunks** —
each superchunk is ONE dispatch of ``2**k * chunk * n_devices`` rows
through a cached ``shard_map`` runner (``parallel/sharding.py``'s compat
wrapper, mesh axis ``"data"``). A bucket of ``u`` units therefore costs
``popcount(u)`` dispatches against an executable family of at most
``log2(u) + 1`` shapes, instead of ``u`` serialized unit dispatches: on
hosts where every dispatch pays a full serial event loop regardless of
its replica-row count (vmap rows are nearly free), this is what keeps
the sharded layout's events/sec at parity with the unsharded
single-dispatch layout. The dispatch loop never blocks — every
superchunk is issued before the first result is touched, chunk operand
buffers are donated, and the host-side aggregation of finished
superchunks overlaps the still-in-flight ones. Edge padding is bounded
below the mesh width (rows are only rounded up to a device-count
multiple); the final superchunk is trimmed to the true remaining rows
rather than padded to a full unit.
``exec_stats()`` exposes the dispatch/compile counters so benchmarks
(``benchmarks/perfcheck.py``) can record the dispatch-count reduction.
``repro.experiments.ExecOptions`` carries (backend, devices, chunk) as
one immutable object through the benchmark suite — there is no
process-wide execution state.
"""
from __future__ import annotations

import functools
import math
import warnings
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.cost_model import CostModel, N_COST_ROWS
from repro.core.sim import (LAT_SAMPLES, SimConfig, SimResult, _run_events,
                            resolve_backend, topology)
from repro.parallel.sharding import shard_map
from repro.workloads import (Workload, WorkloadOperands, as_workload, lower,
                             pad_phases)

# -- execution statistics ----------------------------------------------------
# A "dispatch" is one host->device call of a compiled bucket runner (covering
# every device in its mesh); a "compile" is one new (runner, input shape)
# pair. perfcheck.py records these next to events/sec. "vmem_plan" is the
# most recent event-loop kernel VMEM plan (repro.kernels.event_loop.vmem) —
# tile auto-shrinks and byte totals ride into the benchmark reports with it.
_STATS = {"dispatches": 0, "compiles": 0}
_COMPILED: set = set()


def exec_stats() -> dict:
    """Snapshot of {dispatches, compiles, vmem_plan} since the last reset
    (``vmem_plan`` is None unless the Pallas backend planned a kernel)."""
    from repro.kernels.event_loop import vmem
    st = dict(_STATS)
    plan = vmem.last_plan()
    st["vmem_plan"] = plan.as_dict() if plan is not None else None
    return st


def reset_exec_stats() -> None:
    from repro.kernels.event_loop import vmem
    _STATS["dispatches"] = 0
    _STATS["compiles"] = 0
    vmem.clear_plan()


def _note_call(key) -> None:
    _STATS["dispatches"] += 1
    if key not in _COMPILED:
        _COMPILED.add(key)
        _STATS["compiles"] += 1


def shape_key(cfg, n_events: int):
    """The static-argument tuple that determines a compile: two workloads
    (or SimConfigs) with equal keys can share one XLA executable. The
    final entry is the open-loop request-slot count R (0 = closed loop;
    legacy SimConfigs have no arrivals and are always closed)."""
    arr = getattr(cfg, "arrivals", None)
    return (cfg.alg, cfg.n_nodes * cfg.threads_per_node, cfg.n_nodes,
            cfg.n_locks, n_events, 0 if arr is None else arr.n_requests)


@functools.partial(jax.jit,
                   static_argnames=("alg", "T", "N", "K", "n_events"))
def _run_events_batch(alg, T, N, K, n_events, wl, thread_node, lock_node):
    """One shape bucket: every ``wl`` leaf has leading axis B = C * S
    (cost rows and budgets included — per-phase, per-replica operands).
    thread_node/lock_node are functions of the shape key alone and stay
    unbatched (broadcast)."""
    def point(w):
        return _run_events(alg, T, N, K, n_events, w, thread_node,
                           lock_node)

    return jax.vmap(point)(wl)


# -- sharded bucket runners --------------------------------------------------

_RUNNER_CACHE: dict = {}


def _bucket_runner(key, n_phases: int, backend: str, mesh: Mesh):
    """Cached jitted shard_map runner for one (shape key, P, backend, mesh).

    The wrapped function maps the flattened replica axis onto the mesh's
    ``data`` axis; inside each shard the local block runs through the
    selected backend. One runner serves every superchunk size — jit keys
    executables by input shape, so the power-of-two superchunk family
    upstream compiles at most O(log units) shapes per runner, reused
    across superchunks and buckets (``_note_call`` mirrors this by
    including the superchunk row count in the compile-counter key). The
    workload-operand arguments are donated: each dispatch transfers fresh
    host slices, so their device buffers are dead on return and the
    runtime may reuse them for the outputs; the broadcast
    thread_node/lock_node args are shared across dispatches and are NOT
    donated.
    """
    alg, T, N, K, n_events, R = key
    rep = None
    if backend == "pallas":
        # the clock representation is env-overridable (REPRO_EVENT_CLOCKS)
        # and must key the cached runner, or a mid-process flip would
        # silently reuse a trace of the other representation
        from repro.kernels.event_loop.ops import (default_interpret,
                                                  resolve_representation)
        rep = resolve_representation("auto", default_interpret())
    ck = (key, n_phases, backend, rep,
          tuple(d.id for d in mesh.devices.flat))
    if ck in _RUNNER_CACHE:
        return _RUNNER_CACHE[ck], ck
    n_fields = len(WorkloadOperands._fields)
    n_out = 10 if R else 6      # open loop appends arr/wq/soj/rstat

    def local_block(*args):
        wl = WorkloadOperands(*args[:n_fields])
        tn, ln = args[n_fields:]
        if backend == "pallas":
            from repro.kernels.event_loop.ops import run_events
            return run_events(alg, T, N, K, n_events, wl, tn, ln)
        from repro.kernels.event_loop.ref import run_events_ref
        return run_events_ref(alg, T, N, K, n_events, wl, tn, ln)

    fn = jax.jit(shard_map(
        local_block, mesh,
        in_specs=(P("data"),) * n_fields + (P(), P()),
        out_specs=(P("data"),) * n_out, axis_names={"data"}),
        donate_argnums=tuple(range(n_fields)))
    _RUNNER_CACHE[ck] = fn
    return fn, ck


def _pad_rows(a: np.ndarray, n: int) -> np.ndarray:
    """Edge-pad the leading axis by n rows (duplicates, sliced off after)."""
    if n == 0:
        return a
    return np.concatenate([a, np.repeat(a[-1:], n, axis=0)], axis=0)


class BatchResult(NamedTuple):
    """Per-seed samples + aggregate statistics for one workload.

    ``config`` is the item as passed to ``sweep`` (a ``Workload`` or a
    legacy ``SimConfig``). Sample arrays are stacked over the seed axis S;
    ``result(i)`` recovers the i-th seed as a plain ``SimResult``
    (bitwise-equal to running ``simulate`` with that seed).
    """
    config: object
    n_events: int
    seeds: np.ndarray             # (S,)
    ops: np.ndarray               # (S,)
    sim_ns: np.ndarray            # (S,)
    throughput_mops: np.ndarray   # (S,)
    lat_ns: np.ndarray            # (S, LAT_SAMPLES), -1 padded
    per_thread_ops: np.ndarray    # (S, T)
    reacquires: np.ndarray        # (S,)
    passes: np.ndarray            # (S,)
    # open-loop (Workload.arrivals) extras — None on closed-loop runs
    arr_ns: np.ndarray | None = None      # (S, R) request arrival times
    wait_ns: np.ndarray | None = None     # (S, R) queue waits, -1 padded
    sojourn_ns: np.ndarray | None = None  # (S, R) sojourns, -1 padded
    rstat: np.ndarray | None = None       # (S, R) repro.traffic codes

    @property
    def n_seeds(self) -> int:
        return len(self.seeds)

    @property
    def open_loop(self) -> bool:
        return self.arr_ns is not None

    def result(self, i: int) -> SimResult:
        extras = {}
        if self.open_loop:
            extras = dict(arr_ns=self.arr_ns[i], wait_ns=self.wait_ns[i],
                          sojourn_ns=self.sojourn_ns[i],
                          rstat=self.rstat[i])
        return SimResult(int(self.ops[i]), int(self.sim_ns[i]),
                         float(self.throughput_mops[i]), self.lat_ns[i],
                         self.per_thread_ops[i], int(self.reacquires[i]),
                         int(self.passes[i]), **extras)

    # -- open-loop serving aggregates --------------------------------------

    def serving(self, i: int) -> dict:
        """One seed's ``repro.traffic.metrics.serving_summary`` dict."""
        if not self.open_loop:
            raise ValueError("serving() needs an open-loop run "
                             "(Workload.arrivals)")
        from repro.traffic.metrics import serving_summary
        return serving_summary(self.arr_ns[i], self.wait_ns[i],
                               self.sojourn_ns[i], self.rstat[i],
                               int(self.sim_ns[i]))

    def serving_mean(self) -> dict:
        """Seed-averaged serving summary (nan-safe over empty seeds)."""
        rows = [self.serving(i) for i in range(self.n_seeds)]
        out = {}
        for k in rows[0]:
            vals = np.asarray([r[k] for r in rows], np.float64)
            finite = vals[np.isfinite(vals)]
            out[k] = float(finite.mean()) if len(finite) else float("nan")
        return out

    # -- throughput aggregates ---------------------------------------------

    @property
    def mean_mops(self) -> float:
        return float(self.throughput_mops.mean())

    @property
    def ci95_mops(self) -> float:
        """Half-width of the normal-approx 95% CI of the mean (0 for S=1)."""
        s = self.throughput_mops
        if len(s) < 2:
            return 0.0
        return float(1.96 * s.std(ddof=1) / np.sqrt(len(s)))

    # -- latency aggregates (valid samples only; -1 is padding) ------------

    def _lat_pool(self) -> np.ndarray:
        flat = self.lat_ns.ravel()
        return flat[flat >= 0]

    @property
    def mean_lat_us(self) -> float:
        pool = self._lat_pool()
        return float(pool.mean()) / 1e3 if len(pool) else float("nan")

    @property
    def p50_lat_ns(self) -> float:
        pool = self._lat_pool()
        return float(np.percentile(pool, 50)) if len(pool) else float("nan")

    @property
    def p99_lat_ns(self) -> float:
        pool = self._lat_pool()
        return float(np.percentile(pool, 99)) if len(pool) else float("nan")

    def lat_pct(self, q: float) -> tuple[float, float]:
        """(mean, ci95) of the q-th latency percentile across seeds."""
        per_seed = []
        for row in self.lat_ns:
            valid = row[row >= 0]
            if len(valid):
                per_seed.append(np.percentile(valid, q))
        if not per_seed:
            return float("nan"), 0.0
        per_seed = np.asarray(per_seed, np.float64)
        mean = float(per_seed.mean())
        if len(per_seed) < 2:
            return mean, 0.0
        return mean, float(1.96 * per_seed.std(ddof=1)
                           / np.sqrt(len(per_seed)))


def _exec_bucket(key, thread_node, lock_node, wl: WorkloadOperands,
                 backend: str, devices, chunk):
    """Run one flattened bucket (B rows) and return the 6 output arrays.

    ``wl`` leaves carry the flattened (workload x seed) axis B — the
    per-phase cost rows and budgets included. Unsharded (devices/chunk
    both None): one dispatch for the whole bucket — the XLA leg is the
    original ``_run_events_batch`` oracle. Sharded: the row axis is
    measured in units of ``chunk`` rows per device, the unit count is
    decomposed into greedy power-of-two superchunks, and each superchunk
    is one non-blocking dispatch over the device mesh (see the module
    docstring); aggregation converts finished superchunks while later
    ones are still in flight and only the final concatenate forces the
    last dispatch.
    """
    alg, T, N, K, n_events, R = key
    B = wl.seed.shape[0]
    n_phases = wl.edges.shape[1]
    if devices is None and chunk is None:
        with enable_x64():
            wj = WorkloadOperands(*(jnp.asarray(a) for a in wl))
            if backend == "pallas":
                from repro.kernels.event_loop.ops import (plan_for_run,
                                                          run_events_jit)
                # re-record the VMEM plan per dispatch: planning inside
                # run_events is trace-time only, so a cached executable
                # would otherwise leave exec_stats()["vmem_plan"] stale
                plan_for_run(B, n_phases, n_events, T, N, K, R=R,
                             hl=alg == "hlock", rw=alg == "alock-rw")
                out = run_events_jit(alg, T, N, K, n_events, wj,
                                     thread_node, lock_node)
            else:
                out = _run_events_batch(alg, T, N, K, n_events, wj,
                                        thread_node, lock_node)
        _note_call((key, n_phases, backend, "bucket", B))
        return tuple(np.asarray(o) for o in out)

    devs = list(devices) if devices is not None else jax.devices()
    mesh = Mesh(np.asarray(devs), ("data",))
    D = len(devs)
    rows = int(chunk) if chunk is not None else math.ceil(B / D)
    if rows < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    step = rows * D                       # rows per dispatch unit
    # pad only to the mesh width: shard_map needs each dispatch's row
    # count divisible by D, nothing more — per-replica edge padding is
    # dead serial kernel work on every device, so the final superchunk is
    # trimmed to the true remaining rows instead of a full unit
    Bp = math.ceil(B / D) * D
    n_units = math.ceil(Bp / step)
    # greedy power-of-two decomposition of the unit count: one dispatch
    # per superchunk (popcount(n_units) total), executable family bounded
    # by log2(n_units) + 1 full-unit shapes plus at most one trimmed
    # trailing shape
    sizes, rem = [], n_units
    while rem:
        p = 1 << (rem.bit_length() - 1)
        sizes.append(p)
        rem -= p
    leaves = [_pad_rows(np.asarray(a), Bp - B) for a in wl]
    tn = np.asarray(thread_node)
    ln = np.asarray(lock_node)
    runner, ck = _bucket_runner(key, n_phases, backend, mesh)
    outs = []
    with enable_x64(), warnings.catch_warnings():
        # donated operand buffers only help when an output can reuse one;
        # most of this engine's outputs are clock-typed rings with no
        # matching input shape, so XLA declines those donations with a
        # per-dispatch warning — benign and suppressed here
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        off = 0
        for sz in sizes:
            nrows = min(sz * step, Bp - off)   # multiple of D by induction
            if backend == "pallas":
                # each shard's kernel sees nrows/D replicas (same
                # trace-time-only caveat as the unsharded branch above)
                from repro.kernels.event_loop.ops import plan_for_run
                plan_for_run(nrows // D, n_phases, n_events, T, N, K, R=R,
                             hl=alg == "hlock", rw=alg == "alock-rw")
            sl = slice(off, off + nrows)
            # async: the call returns device futures — every superchunk
            # is issued before any result is forced below
            outs.append(runner(*(a[sl] for a in leaves), tn, ln))
            _note_call((ck, nrows))
            off += nrows
    # aggregation is the only blocking point: np.asarray forces the
    # superchunks in dispatch order, so converting an early (large) one
    # overlaps the later in-flight dispatches
    return tuple(np.concatenate([np.asarray(o[j]) for o in outs])[:B]
                 for j in range(10 if R else 6))


def sweep(configs: Sequence[SimConfig | Workload], n_seeds: int = 1,
          n_events: int = 400_000, cm: CostModel = CostModel(), *,
          backend: str = "auto", devices=None,
          chunk: int | None = None) -> list[BatchResult]:
    """Run every workload with seeds ``w.seed + [0, n_seeds)``; one compile
    per ``shape_key`` bucket (per chunk shape when sharding).

    configs: ``Workload`` specs and/or legacy ``SimConfig`` (adapter).
    backend: "xla" | "pallas" | "auto" — per-replica engine (see module
      docstring); every backend/layout combination returns bitwise-identical
      replicas (tested).
    devices: device list to shard the flattened (workload x seed) axis over
      (mesh axis "data"); None with chunk=None keeps the single-dispatch
      layout.
    chunk: rows per device per dispatch *unit*. Units are coalesced into
      greedy power-of-two superchunks — one dispatch each — so an
      oversized bucket costs popcount(units) dispatches against at most
      log2(units)+1 executable shapes instead of one serialized dispatch
      per unit; chunk=None with devices set derives one even chunk per
      device (a single superchunk).

    Returns BatchResults parallel to ``configs`` (duplicates are simulated
    twice — dedupe upstream if the grid overlaps; ``experiments.Experiment``
    does). ``cm`` is the base cost model every ``cost=None`` workload
    inherits (per-workload/per-phase ``cost`` fields override it row-wise
    without adding compiles).

    >>> from repro.core.batch import sweep
    >>> from repro.workloads import Workload
    >>> rs = sweep([Workload("alock", 2, 2, 8, locality=0.9, seed=1)],
    ...            n_seeds=2, n_events=1500, backend="xla")
    >>> rs[0].ops.shape                  # per-seed samples
    (2,)
    >>> rs[0].mean_mops > 0 and rs[0].p99_lat_ns > 0
    True
    """
    if n_seeds < 1:
        raise ValueError(f"n_seeds must be >= 1, got {n_seeds}")
    backend = resolve_backend(backend)
    configs = list(configs)
    lowered = [lower(as_workload(c), n_events, cm) for c in configs]
    buckets: dict[tuple, list[int]] = {}
    for i, lw in enumerate(lowered):
        buckets.setdefault(lw.shape_key, []).append(i)

    out: list[BatchResult | None] = [None] * len(configs)
    for key, idxs in buckets.items():
        alg, T, N, K, _, R = key
        kpn = K // N
        thread_node, lock_node, _ = topology(alg, N, T // N, K, cm)
        C, S = len(idxs), n_seeds
        # scenarios with fewer phases pad up to the bucket max with
        # unreachable phases, so mixed phase programs share one executable
        # (open-loop arrival rows pad identically; R is part of the key)
        Pmax = max(lowered[i].operands.n_phases for i in idxs)
        loc = np.empty((C, S, Pmax, T), np.float32)
        zc = np.empty((C, S, Pmax, kpn), np.float32)
        ed = np.empty((C, S, Pmax), np.int32)
        th = np.empty((C, S, Pmax), np.int32)
        ac = np.empty((C, S, Pmax, T), np.int32)
        bi = np.empty((C, S, Pmax, 2), np.int32)
        cr = np.empty((C, S, Pmax, N_COST_ROWS), np.int32)
        nm = np.empty((C, S, Pmax, N), np.float32)
        sd = np.empty((C, S), np.int32)
        ag = np.empty((C, S, Pmax), np.float32)
        ae = np.empty((C, S, Pmax), np.int32)
        aq = np.empty((C, S, Pmax), np.int32)
        at = np.empty((C, S, Pmax, 2), np.float32)
        af = np.empty((C, S, R), np.int32)
        rk = np.empty((C, S, N), np.int32)
        rf = np.empty((C, S, Pmax, T), np.float32)
        for row, i in enumerate(idxs):
            o = pad_phases(lowered[i].operands, Pmax)
            loc[row], zc[row], ed[row] = o.locality, o.zcdf, o.edges
            th[row], ac[row], bi[row] = o.think_ns, o.active, o.b_init
            cr[row], nm[row] = o.cost_rows, o.node_mult
            ag[row], ae[row], aq[row] = (o.arr_gap_ns, o.arr_edges,
                                         o.arr_qcap)
            at[row], af[row] = o.arr_token, o.arr_fix
            rk[row], rf[row] = o.rack, o.read_frac
            sd[row] = int(o.seed) + np.arange(S, dtype=np.int32)

        def flat(a):
            return a.reshape((C * S,) + a.shape[2:])

        wl = WorkloadOperands(flat(loc), flat(zc), flat(ed), flat(th),
                              flat(ac), flat(bi), flat(sd), flat(cr),
                              flat(nm), flat(ag), flat(ae), flat(aq),
                              flat(at), flat(af), flat(rk), flat(rf))
        outs = _exec_bucket(
            key, thread_node, lock_node, wl, backend, devices, chunk)
        done, lat, _lat_n, t_end, nreacq, npass = outs[:6]
        done = done.reshape(C, S, T)
        lat = lat.reshape(C, S, LAT_SAMPLES)
        t_end = t_end.reshape(C, S)
        nreacq = nreacq.reshape(C, S)
        npass = npass.reshape(C, S)
        extras = None
        if R:
            extras = tuple(o.reshape(C, S, R) for o in outs[6:])

        for row, i in enumerate(idxs):
            ops = done[row].sum(axis=1).astype(np.int64)
            sim_ns = np.maximum(t_end[row].astype(np.int64), 1)
            # per-element arithmetic matches simulate()'s scalar formula
            # bitwise: ops / sim_ns * 1e3 in float64 either way
            mops = ops / sim_ns * 1e3
            kw = {}
            if extras is not None:
                kw = dict(arr_ns=extras[0][row], wait_ns=extras[1][row],
                          sojourn_ns=extras[2][row], rstat=extras[3][row])
            out[i] = BatchResult(configs[i], n_events, sd[row], ops,
                                 sim_ns, mops, lat[row], done[row],
                                 nreacq[row], npass[row], **kw)
    return out

"""Canonical ALock / RDMA-spinlock / RDMA-MCS state machines.

Pure step functions over immutable tuples, mirroring the paper's TLA+ spec
(Appendix A) program counters. One source of truth consumed by
  - core/tla.py          exhaustive model checking (mutex, deadlock, ...)
  - tests (hypothesis)   adversarial schedule exploration
  - core/sim.py          the vectorized JAX event simulator (same PCs in
                         jnp; cross-validated step-for-step against this)

Machine model
-------------
A single ALock guards one resource; threads are permanently assigned a
cohort for a given request: LOCAL(0) threads use shared-memory ops, REMOTE(1)
threads use RDMA ops. The two MCS tails double as Peterson flags (tail != 0
<=> cohort interested/holding) and `victim` arbitrates between cohort
leaders. Budgets bound consecutive intra-cohort lock passes (Dice et al.
style); a thread passed budget 0 must re-run Peterson (pReacquire) before
entering, restoring inter-cohort fairness.

Each step is one atomic shared-memory/RDMA access (the swap is modeled as an
atomic fetch-and-swap — the paper emulates it with an rCAS retry loop, which
is linearizable to the same thing; the retry cost is charged in the cost
model, not in the semantics).
"""
from __future__ import annotations

from typing import NamedTuple

LOCAL, REMOTE = 0, 1

# --- program counters (shared by all machines; not all used by all) -------
NCS = 0          # non-critical section; next step begins a request
SWAP = 1         # MCS: swap own descriptor into cohort tail
WRITE_NEXT = 2   # MCS: link into predecessor's next pointer
SPIN_BUDGET = 3  # MCS: local-spin until budget passed (>= 0)
SET_VICTIM = 4   # Peterson: victim := my cohort  (first acquisition)
PET_WAIT = 5     # Peterson: wait (victim != me) or (other tail == 0)
SET_VICTIM_R = 6  # Peterson re-acquire path (budget exhausted)
PET_WAIT_R = 7
CS = 8           # critical section
REL_CAS = 9      # release: CAS tail from self back to 0
SPIN_NEXT = 10   # release: wait for successor to link itself
PASS = 11        # release: write successor budget (budget - 1)
# spinlock-only
SL_CAS = 12      # spin: CAS word 0 -> tid
SL_REL = 13      # write word back to 0
# reader-writer ALock only (alock-rw)
RD_TRY = 14      # reader: enter + word++ iff both tails empty
RD_CS = 15       # reader critical section (shared)
RD_REL = 16      # reader release: word--
WR_DRAIN = 17    # writer: wait for reader count (word) to drain to 0

PC_NAMES = {v: k for k, v in list(globals().items()) if isinstance(v, int)}


class LockState(NamedTuple):
    """One lock + all thread descriptors (tids are 0-based; slots store
    tid+1 with 0 = null)."""
    tail: tuple            # (tail_local, tail_remote) — Peterson flags
    victim: int            # cohort id 0/1
    budget: tuple          # per-thread descriptor budget (-1 = waiting)
    next: tuple            # per-thread descriptor next pointer (tid+1)
    pc: tuple              # per-thread program counter
    prev: tuple            # per-thread remembered predecessor (tid+1)
    word: int = 0          # spinlock/MCS lock word (tid+1); rw reader count


class Op(NamedTuple):
    """What a step did — consumed by cost models and fairness accounting."""
    label: str             # e.g. "swap", "pet_check", "spin", ...
    kind: str              # "local" | "remote" | "none"
    progressed: bool       # False for an unsuccessful spin re-check


def initial_state(n_threads: int, victim: int = 0) -> LockState:
    z = (0,) * n_threads
    return LockState(tail=(0, 0), victim=victim, budget=(-1,) * n_threads,
                     next=z, pc=(NCS,) * n_threads, prev=z, word=0)


def _set(t: tuple, i: int, v) -> tuple:
    return t[:i] + (v,) + t[i + 1:]


def _opk(cohort: int) -> str:
    return "local" if cohort == LOCAL else "remote"


# ---------------------------------------------------------------------------
# ALock


def alock_step(st: LockState, tid: int, cohort: int,
               b_init: tuple[int, int]) -> tuple[LockState, Op]:
    """Advance thread `tid` (in `cohort`) by one atomic action.

    b_init = (local_budget, remote_budget): kInitBudget per cohort.
    """
    c = cohort
    pc = st.pc[tid]
    B = b_init[c]
    me = tid + 1

    if pc == NCS:
        # c1: fresh descriptor
        st = st._replace(budget=_set(st.budget, tid, -1),
                         next=_set(st.next, tid, 0),
                         pc=_set(st.pc, tid, SWAP))
        return st, Op("desc_init", "local", True)

    if pc == SWAP:
        prev = st.tail[c]
        st = st._replace(tail=_set(st.tail, c, me),
                         prev=_set(st.prev, tid, prev))
        if prev == 0:
            # queue was empty: budget reset, must run Peterson (not passed)
            st = st._replace(budget=_set(st.budget, tid, B),
                             pc=_set(st.pc, tid, SET_VICTIM))
        else:
            st = st._replace(pc=_set(st.pc, tid, WRITE_NEXT))
        return st, Op("swap", _opk(c), True)

    if pc == WRITE_NEXT:
        p = st.prev[tid] - 1
        st = st._replace(next=_set(st.next, p, me),
                         pc=_set(st.pc, tid, SPIN_BUDGET))
        return st, Op("write_next", _opk(c), True)

    if pc == SPIN_BUDGET:
        b = st.budget[tid]
        if b == -1:
            return st, Op("spin_budget", "none", False)  # local spin
        if b == 0:
            st = st._replace(pc=_set(st.pc, tid, SET_VICTIM_R))
            return st, Op("budget_zero", "local", True)
        st = st._replace(pc=_set(st.pc, tid, CS))
        return st, Op("passed", "local", True)

    if pc in (SET_VICTIM, SET_VICTIM_R):
        nxt = PET_WAIT if pc == SET_VICTIM else PET_WAIT_R
        st = st._replace(victim=c, pc=_set(st.pc, tid, nxt))
        return st, Op("set_victim", _opk(c), True)

    if pc in (PET_WAIT, PET_WAIT_R):
        # one 64B read observes (tail_l, tail_r, victim) together (Fig. 3)
        if st.tail[1 - c] == 0 or st.victim != c:
            if pc == PET_WAIT_R:
                st = st._replace(budget=_set(st.budget, tid, B))
            st = st._replace(pc=_set(st.pc, tid, CS))
            return st, Op("pet_acquired", _opk(c), True)
        return st, Op("pet_check", _opk(c), False)

    if pc == CS:
        st = st._replace(pc=_set(st.pc, tid, REL_CAS))
        return st, Op("cs", "none", True)

    if pc == REL_CAS:
        if st.tail[c] == me:
            st = st._replace(tail=_set(st.tail, c, 0),
                             pc=_set(st.pc, tid, NCS))
            return st, Op("rel_cas_ok", _opk(c), True)
        st = st._replace(pc=_set(st.pc, tid, SPIN_NEXT))
        return st, Op("rel_cas_fail", _opk(c), True)

    if pc == SPIN_NEXT:
        if st.next[tid] == 0:
            return st, Op("spin_next", "none", False)
        st = st._replace(pc=_set(st.pc, tid, PASS))
        return st, Op("succ_seen", "local", True)

    if pc == PASS:
        succ = st.next[tid] - 1
        st = st._replace(budget=_set(st.budget, succ, st.budget[tid] - 1),
                         pc=_set(st.pc, tid, NCS))
        return st, Op("pass", _opk(c), True)

    raise AssertionError(f"bad pc {pc}")


# ---------------------------------------------------------------------------
# RDMA spinlock (competitor): every op through the RNIC, incl. loopback


def spinlock_step(st: LockState, tid: int, cohort: int,
                  _b=None) -> tuple[LockState, Op]:
    pc = st.pc[tid]
    me = tid + 1
    if pc == NCS:
        st = st._replace(pc=_set(st.pc, tid, SL_CAS))
        return st, Op("desc_init", "local", True)
    if pc == SL_CAS:
        if st.word == 0:
            st = st._replace(word=me, pc=_set(st.pc, tid, CS))
            return st, Op("cas_ok", "remote", True)
        return st, Op("cas_fail", "remote", False)   # remote spinning!
    if pc == CS:
        st = st._replace(pc=_set(st.pc, tid, SL_REL))
        return st, Op("cs", "none", True)
    if pc == SL_REL:
        st = st._replace(word=0, pc=_set(st.pc, tid, NCS))
        return st, Op("rel_write", "remote", True)
    raise AssertionError(f"bad pc {pc}")


# ---------------------------------------------------------------------------
# RDMA MCS (competitor): single queue, lock-word ops via RNIC (loopback for
# local threads), budget-free; spins locally on own descriptor.


def mcs_step(st: LockState, tid: int, cohort: int,
             _b=None) -> tuple[LockState, Op]:
    pc = st.pc[tid]
    me = tid + 1
    if pc == NCS:
        st = st._replace(budget=_set(st.budget, tid, -1),
                         next=_set(st.next, tid, 0),
                         pc=_set(st.pc, tid, SWAP))
        return st, Op("desc_init", "local", True)
    if pc == SWAP:
        prev = st.word
        st = st._replace(word=me, prev=_set(st.prev, tid, prev))
        if prev == 0:
            st = st._replace(pc=_set(st.pc, tid, CS))
        else:
            st = st._replace(pc=_set(st.pc, tid, WRITE_NEXT))
        return st, Op("swap", "remote", True)
    if pc == WRITE_NEXT:
        p = st.prev[tid] - 1
        st = st._replace(next=_set(st.next, p, me),
                         pc=_set(st.pc, tid, SPIN_BUDGET))
        return st, Op("write_next", "remote", True)
    if pc == SPIN_BUDGET:
        if st.budget[tid] == -1:
            return st, Op("spin_budget", "none", False)  # local spin
        st = st._replace(pc=_set(st.pc, tid, CS))
        return st, Op("passed", "local", True)
    if pc == CS:
        st = st._replace(pc=_set(st.pc, tid, REL_CAS))
        return st, Op("cs", "none", True)
    if pc == REL_CAS:
        if st.word == me:
            st = st._replace(word=0, pc=_set(st.pc, tid, NCS))
            return st, Op("rel_cas_ok", "remote", True)
        st = st._replace(pc=_set(st.pc, tid, SPIN_NEXT))
        return st, Op("rel_cas_fail", "remote", True)
    if pc == SPIN_NEXT:
        if st.next[tid] == 0:
            return st, Op("spin_next", "none", False)
        st = st._replace(pc=_set(st.pc, tid, PASS))
        return st, Op("succ_seen", "local", True)
    if pc == PASS:
        succ = st.next[tid] - 1
        st = st._replace(budget=_set(st.budget, succ, 1),
                         pc=_set(st.pc, tid, NCS))
        return st, Op("pass", "remote", True)
    raise AssertionError(f"bad pc {pc}")


# ---------------------------------------------------------------------------
# Hierarchical topology-aware lock (hlock): the ALock protocol verbatim —
# the generalization lives entirely in how the *caller* derives `cohort`
# (rack-of-thread vs rack-of-lock instead of node-of-thread vs
# node-of-lock) and in the cost tiers charged per op (same node / same
# rack / cross rack). Keeping the PC-level protocol identical to
# `alock_step` is what makes the trivial topology (every node its own
# rack) bitwise-equal to the flat ALock — the regression anchor the
# simulator tests pin.


def hlock_step(st: LockState, tid: int, cohort: int,
               b_init: tuple[int, int]) -> tuple[LockState, Op]:
    return alock_step(st, tid, cohort, b_init)


# ---------------------------------------------------------------------------
# Reader-writer ALock (alock-rw): writers run the full ALock protocol but
# drain the shared reader count (kept in `word`, unused by the plain
# ALock) before entering the CS; readers bypass the MCS/Peterson machinery
# entirely — they increment `word` iff both cohort tails are empty
# (writer preference: any queued writer blocks new readers) and share the
# CS among themselves. A reader holds from the successful RD_TRY until
# its RD_REL decrement executes.


def alock_rw_step(st: LockState, tid: int, cohort: int,
                  b_init: tuple[int, int],
                  is_read: bool = False) -> tuple[LockState, Op]:
    pc = st.pc[tid]

    if pc == NCS and is_read:
        # descriptor reset mirrors the writer arm (and the jnp engine's
        # unconditional NCS re-arm) even though readers never queue
        st = st._replace(budget=_set(st.budget, tid, -1),
                         next=_set(st.next, tid, 0),
                         pc=_set(st.pc, tid, RD_TRY))
        return st, Op("desc_init", "local", True)

    if pc == RD_TRY:
        if st.tail[0] == 0 and st.tail[1] == 0:
            st = st._replace(word=st.word + 1,
                             pc=_set(st.pc, tid, RD_CS))
            return st, Op("rd_enter", _opk(cohort), True)
        return st, Op("rd_blocked", _opk(cohort), False)

    if pc == RD_CS:
        st = st._replace(pc=_set(st.pc, tid, RD_REL))
        return st, Op("rd_cs", "none", True)

    if pc == RD_REL:
        st = st._replace(word=st.word - 1, pc=_set(st.pc, tid, NCS))
        return st, Op("rd_rel", _opk(cohort), True)

    if pc == WR_DRAIN:
        if st.word == 0:
            st = st._replace(pc=_set(st.pc, tid, CS))
            return st, Op("wr_drained", _opk(cohort), True)
        return st, Op("wr_drain", _opk(cohort), False)

    # writer path: the plain ALock, with every CS entry rerouted through
    # the reader drain
    nst, op = alock_step(st, tid, cohort, b_init)
    if nst.pc[tid] == CS and pc != WR_DRAIN:
        nst = nst._replace(pc=_set(nst.pc, tid, WR_DRAIN))
    return nst, op


MACHINES = {"alock": alock_step, "spinlock": spinlock_step, "mcs": mcs_step,
            "hlock": hlock_step, "alock-rw": alock_rw_step}


def in_cs(st: LockState, tid: int) -> bool:
    return st.pc[tid] == CS


def in_read_cs(st: LockState, tid: int) -> bool:
    """Reader holds the shared CS from rd_enter until its RD_REL
    decrement has executed (pc back at NCS)."""
    return st.pc[tid] in (RD_CS, RD_REL)


def wants_lock(st: LockState, tid: int) -> bool:
    return st.pc[tid] not in (NCS, CS, REL_CAS, SPIN_NEXT, PASS, SL_REL,
                              RD_CS, RD_REL)

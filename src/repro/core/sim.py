"""Vectorized discrete-event simulator for the distributed lock table.

The lock machines from ``core/machine.py`` re-expressed over JAX arrays and
driven by a next-event loop (`lax.fori_loop` + argmin over per-thread ready
times). Every shared-state mutation is serialized through the single event
queue, so executions are linearizable by construction — the same PC/semantic
transitions as the Python machines (cross-validated in tests via
``run_schedule``).

Time is int64 nanoseconds (``simulate``/``batch.sweep`` locally enable x64 so
the clock arrays really are 64-bit; semantic ``Sem`` state stays int32).
int32 clocks wrap after ~2.1s of simulated time — roughly 2M events at
~1us/event — which silently corrupts the argmin event order, so widening is
correctness, not hygiene. f32 time would likewise lose sub-ulp increments
past ~10ms.

Two execution backends share this module's semantics:

  * ``backend="xla"`` — the original serial ``lax.fori_loop`` over
    ``sem_step`` (argmin + ``lax.switch`` per event). This path is the
    correctness oracle.
  * ``backend="pallas"`` — ``repro.kernels.event_loop``: the same loop as a
    Pallas kernel with all per-replica state (Sem, ready/busy clocks,
    latency ring) resident in VMEM for the whole run, replicas tiled across
    the grid, branch dispatch re-expressed as masked ``jnp.select`` over PC
    classes. Bitwise-identical outputs to the XLA path (tested); the
    workload draws are precomputed per event from the same counter-based
    ``jax.random.fold_in`` stream so per-seed results match exactly.

``backend="auto"`` picks pallas on TPU and the XLA loop elsewhere; asking
for pallas explicitly on CPU runs the kernel in interpret mode.

Workloads — the declarative front door
--------------------------------------
The engines consume ``repro.workloads.WorkloadOperands``: the lowered form
of a declarative ``repro.workloads.Workload`` spec. *Everything* workload-
shaped is a traced operand — per-phase **per-thread** locality ``(P, T)``,
per-phase Zipf CDFs ``(P, kpn)``, phase boundaries over the event axis
(``edges``), per-phase think times, a per-phase active-thread mask
(node join/leave churn), per-phase **cost rows** (the 8 integer-ns cost
scalars, so a phase can swap the whole RDMA cost table — congested vs
idle NIC) and per-phase **ALock budgets** ``b_init``. At event ``i``
thread ``tid`` first resolves its phase (``sum(i >= edges) - 1``), then
draws a node (own node with probability ``locality[phase, tid]``, else
uniform remote) and a lock within that node by inverse-CDF from
``zcdf[phase]``; the step's cost and any budget it arms come from
``cost_rows[phase]`` / ``b_init[phase]``. Threads whose node is down in
the current phase are never scheduled (masked out of the ready-time
argmin). Per-phase **node multipliers** ``node_mult (P, N)`` inject
fail-slow degradation: every cost is scaled by the multiplier of the
node that *performs* the work — RNIC service and wire time by the card's
node, plain CPU-side ops (local/poll/cs/think) by the calling thread's
node — so one limping node drags exactly the traffic that touches it.

Because only ``(alg, T, N, K, n_events)`` — plus the phase count via
operand *shapes* — is static, a ``batch.sweep`` mixing arbitrary
scenarios (locality mixes, hot-key storms, churn programs, cost-profile
bursts, budget ramps) compiles once per shape bucket.

``simulate`` accepts a ``Workload`` directly, or a legacy flat
``SimConfig`` through the bitwise-faithful ``from_simconfig`` adapter.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64

from repro.core import machine as mc
from repro.core.cost_model import CostModel
from repro.workloads import (Workload, WorkloadOperands, as_workload, lower,
                             zipf_cdf)

__all__ = [
    "SimConfig", "SimResult", "Sem", "simulate", "topology", "zipf_cdf",
    "resolve_backend", "init_sem", "sem_step", "run_schedule",
    "Workload", "WorkloadOperands", "LAT_SAMPLES",
]

I32 = jnp.int32
I64 = jnp.int64

# cost opcodes emitted by semantic branches
OP_LOCAL, OP_POLL, OP_CS, OP_THINK, OP_RDMA, OP_LOOP = range(6)


class Sem(NamedTuple):
    """Semantic (cost-free) simulator state."""
    tail: jax.Array     # (K,2) tid+1 per cohort
    victim: jax.Array   # (K,)
    word: jax.Array     # (K,) competitor lock word
    budget: jax.Array   # (T,)
    nxt: jax.Array      # (T,)
    prev: jax.Array     # (T,)
    pc: jax.Array       # (T,)
    target: jax.Array   # (T,) lock index
    cohort: jax.Array   # (T,) 0 local / 1 remote


def init_sem(n_threads: int, n_locks: int, targets=None,
             cohorts=None) -> Sem:
    T, K = n_threads, n_locks
    z = jnp.zeros(T, I32)
    return Sem(
        tail=jnp.zeros((K, 2), I32), victim=jnp.zeros(K, I32),
        word=jnp.zeros(K, I32), budget=jnp.full(T, -1, I32), nxt=z, prev=z,
        pc=jnp.full(T, mc.NCS, I32),
        target=(jnp.zeros(T, I32) if targets is None else
                jnp.asarray(targets, I32)),
        cohort=(jnp.zeros(T, I32) if cohorts is None else
                jnp.asarray(cohorts, I32)),
    )


def _step_fns(alg: str, b_init, thread_node, lock_node, rack=None):
    """Build per-PC branch functions: (sem, tid, new_target, new_cohort,
    new_read) -> (sem', opcode, node). Semantics mirror machine.py exactly.

    ``rack`` is the per-node rack-id vector driving hlock's cost tiers
    (same node / same rack / cross rack); ``None`` is the trivial
    topology — every node its own rack — under which the tiers collapse
    to the flat ALock's local/RDMA split.
    """
    b_init = jnp.asarray(b_init, I32)
    thread_node = jnp.asarray(thread_node, I32)
    lock_node = jnp.asarray(lock_node, I32)
    is_hl = alg == "hlock"
    is_rw = alg == "alock-rw"
    # hlock and alock-rw run the ALock tail/victim/budget machinery
    is_alock = alg in ("alock", "hlock", "alock-rw")
    is_mcs = alg == "mcs"
    is_spin = alg == "spinlock"
    if rack is not None:
        rack = jnp.asarray(rack, I32)

    def _rack_of(node_ids):
        return node_ids if rack is None else rack[node_ids]

    def _tiered(node, tid):
        """hlock cost tier: own node -> shared memory, same rack -> the
        cheap loopback/rack fabric, cross rack -> full RDMA."""
        same_rack = _rack_of(node) == _rack_of(thread_node[tid])
        return jnp.where(node == thread_node[tid], OP_LOCAL,
                         jnp.where(same_rack, OP_LOOP, OP_RDMA))

    def lock_op_cost(s, tid):
        """RDMA unless (alock AND local-cohort). Loopback when the RDMA
        target is the caller's own node (competitors only); hlock charges
        the three-tier node/rack/remote split."""
        k = s.target[tid]
        node = lock_node[k]
        if is_hl:
            code = _tiered(node, tid)
        elif is_alock:
            code = jnp.where(s.cohort[tid] == 0, OP_LOCAL, OP_RDMA)
        else:
            code = jnp.where(node == thread_node[tid], OP_LOOP, OP_RDMA)
        return code.astype(I32), node

    def peer_op_cost(s, tid, peer):
        """Write to another thread's descriptor (lives on its node)."""
        node = thread_node[peer]
        if is_hl:
            code = _tiered(node, tid)
        elif is_alock:
            code = jnp.where(node == thread_node[tid], OP_LOCAL, OP_RDMA)
        else:
            code = jnp.where(node == thread_node[tid], OP_LOOP, OP_RDMA)
        return code.astype(I32), node

    def f_ncs(s, tid, new_t, new_c, new_r):
        if is_rw:
            first = jnp.where(new_r != 0, mc.RD_TRY, mc.SWAP)
        else:
            first = mc.SL_CAS if is_spin else mc.SWAP
        s = s._replace(budget=s.budget.at[tid].set(-1),
                       nxt=s.nxt.at[tid].set(0),
                       target=s.target.at[tid].set(new_t),
                       cohort=s.cohort.at[tid].set(new_c),
                       pc=s.pc.at[tid].set(first))
        return s, jnp.int32(OP_THINK), jnp.int32(0)

    def f_swap(s, tid, *_):
        k = s.target[tid]
        c = jnp.where(jnp.int32(is_alock), s.cohort[tid], 0)
        prev = jnp.where(jnp.int32(is_alock), s.tail[k, c], s.word[k])
        me = tid + 1
        if is_alock:
            s = s._replace(tail=s.tail.at[k, c].set(me))
        else:
            s = s._replace(word=s.word.at[k].set(me))
        s = s._replace(prev=s.prev.at[tid].set(prev))
        empty = prev == 0
        if is_alock:
            nxt_pc = jnp.where(empty, mc.SET_VICTIM, mc.WRITE_NEXT)
            s = s._replace(budget=s.budget.at[tid].set(
                jnp.where(empty, b_init[s.cohort[tid]], s.budget[tid])))
        else:
            nxt_pc = jnp.where(empty, mc.CS, mc.WRITE_NEXT)
        s = s._replace(pc=s.pc.at[tid].set(nxt_pc))
        code, node = lock_op_cost(s, tid)
        return s, code, node

    def f_write_next(s, tid, *_):
        p = s.prev[tid] - 1
        s = s._replace(nxt=s.nxt.at[p].set(tid + 1),
                       pc=s.pc.at[tid].set(mc.SPIN_BUDGET))
        code, node = peer_op_cost(s, tid, p)
        return s, code, node

    # a writer's every CS entry detours through the reader drain (rw only)
    enter_cs = mc.WR_DRAIN if is_rw else mc.CS

    def f_spin_budget(s, tid, *_):
        b = s.budget[tid]
        if is_alock:
            nxt_pc = jnp.where(b == -1, mc.SPIN_BUDGET,
                               jnp.where(b == 0, mc.SET_VICTIM_R, enter_cs))
        else:
            nxt_pc = jnp.where(b == -1, mc.SPIN_BUDGET, mc.CS)
        s = s._replace(pc=s.pc.at[tid].set(nxt_pc))
        code = jnp.where(b == -1, OP_POLL, OP_LOCAL)
        return s, code.astype(I32), jnp.int32(0)

    def f_set_victim(s, tid, *_):
        k = s.target[tid]
        s = s._replace(victim=s.victim.at[k].set(s.cohort[tid]),
                       pc=s.pc.at[tid].set(mc.PET_WAIT))
        code, node = lock_op_cost(s, tid)
        return s, code, node

    def f_set_victim_r(s, tid, *_):
        k = s.target[tid]
        s = s._replace(victim=s.victim.at[k].set(s.cohort[tid]),
                       pc=s.pc.at[tid].set(mc.PET_WAIT_R))
        code, node = lock_op_cost(s, tid)
        return s, code, node

    def _pet(s, tid, reacq):
        k = s.target[tid]
        c = s.cohort[tid]
        can = (s.tail[k, 1 - c] == 0) | (s.victim[k] != c)
        if reacq:
            s = s._replace(budget=s.budget.at[tid].set(
                jnp.where(can, b_init[c], s.budget[tid])))
        stay = mc.PET_WAIT_R if reacq else mc.PET_WAIT
        s = s._replace(pc=s.pc.at[tid].set(jnp.where(can, enter_cs, stay)))
        code, node = lock_op_cost(s, tid)
        return s, code, node

    def f_pet_wait(s, tid, *_):
        return _pet(s, tid, False)

    def f_pet_wait_r(s, tid, *_):
        return _pet(s, tid, True)

    def f_cs(s, tid, *_):
        s = s._replace(pc=s.pc.at[tid].set(
            mc.SL_REL if is_spin else mc.REL_CAS))
        return s, jnp.int32(OP_CS), jnp.int32(0)

    def f_rel_cas(s, tid, *_):
        k = s.target[tid]
        me = tid + 1
        if is_alock:
            c = s.cohort[tid]
            solo = s.tail[k, c] == me
            s = s._replace(tail=s.tail.at[k, c].set(
                jnp.where(solo, 0, s.tail[k, c])))
        else:
            solo = s.word[k] == me
            s = s._replace(word=s.word.at[k].set(
                jnp.where(solo, 0, s.word[k])))
        s = s._replace(pc=s.pc.at[tid].set(
            jnp.where(solo, mc.NCS, mc.SPIN_NEXT)))
        code, node = lock_op_cost(s, tid)
        return s, code, node

    def f_spin_next(s, tid, *_):
        has = s.nxt[tid] != 0
        s = s._replace(pc=s.pc.at[tid].set(
            jnp.where(has, mc.PASS, mc.SPIN_NEXT)))
        return s, jnp.where(has, OP_LOCAL, OP_POLL).astype(I32), jnp.int32(0)

    def f_pass(s, tid, *_):
        succ = s.nxt[tid] - 1
        newb = jnp.where(jnp.int32(is_alock), s.budget[tid] - 1, 1)
        s = s._replace(budget=s.budget.at[succ].set(newb),
                       pc=s.pc.at[tid].set(mc.NCS))
        code, node = peer_op_cost(s, tid, succ)
        return s, code, node

    def f_sl_cas(s, tid, *_):
        k = s.target[tid]
        free = s.word[k] == 0
        s = s._replace(word=s.word.at[k].set(
            jnp.where(free, tid + 1, s.word[k])),
            pc=s.pc.at[tid].set(jnp.where(free, mc.CS, mc.SL_CAS)))
        code, node = lock_op_cost(s, tid)
        return s, code, node

    def f_sl_rel(s, tid, *_):
        k = s.target[tid]
        s = s._replace(word=s.word.at[k].set(0),
                       pc=s.pc.at[tid].set(mc.NCS))
        code, node = lock_op_cost(s, tid)
        return s, code, node

    # --- reader-writer branches (alock-rw only; PCs 14..17) --------------
    def f_rd_try(s, tid, *_):
        # reader entry with writer preference: both cohort tails empty
        # means no writer holds or wants the lock; the shared reader
        # count lives in `word` (unused by the plain ALock)
        k = s.target[tid]
        can = (s.tail[k, 0] == 0) & (s.tail[k, 1] == 0)
        s = s._replace(word=s.word.at[k].add(can.astype(I32)),
                       pc=s.pc.at[tid].set(
                           jnp.where(can, mc.RD_CS, mc.RD_TRY)))
        code, node = lock_op_cost(s, tid)
        return s, code, node

    def f_rd_cs(s, tid, *_):
        s = s._replace(pc=s.pc.at[tid].set(mc.RD_REL))
        return s, jnp.int32(OP_CS), jnp.int32(0)

    def f_rd_rel(s, tid, *_):
        k = s.target[tid]
        s = s._replace(word=s.word.at[k].add(-1),
                       pc=s.pc.at[tid].set(mc.NCS))
        code, node = lock_op_cost(s, tid)
        return s, code, node

    def f_wr_drain(s, tid, *_):
        k = s.target[tid]
        can = s.word[k] == 0
        s = s._replace(pc=s.pc.at[tid].set(
            jnp.where(can, mc.CS, mc.WR_DRAIN)))
        code, node = lock_op_cost(s, tid)
        return s, code, node

    fns = [f_ncs, f_swap, f_write_next, f_spin_budget, f_set_victim,
           f_pet_wait, f_set_victim_r, f_pet_wait_r, f_cs, f_rel_cas,
           f_spin_next, f_pass, f_sl_cas, f_sl_rel]
    if is_rw:
        # the rw PCs are unreachable for every other machine — gating them
        # out python-level keeps the other algorithms' traces identical
        fns += [f_rd_try, f_rd_cs, f_rd_rel, f_wr_drain]
    return fns


def sem_step(alg, sem: Sem, tid, b_init, thread_node, lock_node,
             new_target=None, new_cohort=None, new_read=None, rack=None):
    """One semantic step of thread `tid` — used by the event loop and by the
    schedule-driven cross-validation runner. ``new_read`` routes the
    NCS re-arm to the reader path (alock-rw); ``rack`` is the per-node
    rack-id vector hlock's cost tiers consume."""
    fns = _step_fns(alg, b_init, thread_node, lock_node, rack)
    nt = sem.target[tid] if new_target is None else new_target
    nc = sem.cohort[tid] if new_cohort is None else new_cohort
    nr = jnp.int32(0) if new_read is None else new_read
    return lax.switch(sem.pc[tid], fns, sem, tid, nt, nc, nr)


def run_schedule(alg, cohorts, b_init, schedule, n_locks: int = 1):
    """Drive the jnp machine with an explicit thread schedule (single lock,
    semantics only) and return the trace of (pc, tail, victim, budget)."""
    T = len(cohorts)
    sem = init_sem(T, n_locks, targets=[0] * T, cohorts=cohorts)
    tn = [0 if c == 0 else 1 for c in cohorts]   # arbitrary node split
    ln = [0] * n_locks

    def body(sem, tid):
        sem, _, _ = sem_step(alg, sem, tid, b_init, tn, ln)
        return sem, (sem.pc, sem.tail[0], sem.victim[0], sem.budget)

    sem, trace = lax.scan(body, sem, jnp.asarray(schedule, I32))
    return sem, trace


# ---------------------------------------------------------------------------
# Event-driven simulation with the cost model


class SimConfig(NamedTuple):
    """Legacy flat per-run config.

    .. deprecated::
        Kept as a compatibility front door only — it can express neither
        per-thread locality nor phases. New code should build
        ``repro.workloads.Workload`` specs; ``simulate``/``batch.sweep``
        route SimConfig through the bitwise-faithful
        ``repro.workloads.from_simconfig`` adapter.
    """
    alg: str
    n_nodes: int
    threads_per_node: int
    n_locks: int
    locality: float           # P(target lock is on own node)
    b_init: tuple = (5, 20)   # (local, remote) budgets
    seed: int = 0
    zipf_s: float = 0.0       # Zipf skew of the per-node lock choice


def resolve_backend(backend: str) -> str:
    """'auto' -> pallas where natively supported (TPU), else the XLA loop.

    Explicitly requesting 'pallas' off-TPU runs the kernel in interpret
    mode (slow, but bitwise-faithful — that is what the equivalence tests
    exercise on CPU CI).
    """
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend not in ("xla", "pallas"):
        raise ValueError(
            f"backend must be 'xla', 'pallas' or 'auto', got {backend!r}")
    return backend


class SimResult(NamedTuple):
    ops: int
    sim_ns: int
    throughput_mops: float    # million lock+unlock ops per second
    lat_ns: jax.Array         # latency samples (ns), -1 padded
    per_thread_ops: jax.Array
    reacquires: int = 0       # budget-exhaustion pReacquire events
    passes: int = 0           # MCS lock passes
    # open-loop (Workload.arrivals) extras — None on closed-loop runs
    arr_ns: jax.Array | None = None    # (R,) request arrival times
    wait_ns: jax.Array | None = None   # (R,) queue wait, -1 = never served
    sojourn_ns: jax.Array | None = None  # (R,) total, -1 = never completed
    rstat: jax.Array | None = None     # (R,) repro.traffic status codes


LAT_SAMPLES = 1 << 15


def _scale_cost(c, m):
    """Apply a fail-slow node multiplier to an integer-ns cost.

    Round-to-nearest in float32: cost rows are < 2^24 ns so the f32
    product is exact, which makes ``m == 1.0`` bitwise inert (the
    healthy-cluster path reproduces the pre-fault engine exactly) and
    keeps the scaled delta i32 — both clock representations of the
    Pallas kernel consume it unchanged. The kernel mirrors this formula
    verbatim; any change here must be mirrored there.
    """
    return jnp.round(jnp.asarray(c, jnp.float32) * m).astype(I32)


def _run_events(alg, T, N, K, n_events, wl: WorkloadOperands, thread_node,
                lock_node, lat_samples: int = LAT_SAMPLES):
    """Serial next-event loop for one (workload, seed) point — XLA backend.

    Plain (unjitted) so callers can compose it: ``simulate`` jits it directly
    (``_run_events_jit``), ``batch.sweep`` vmaps it over a flattened
    (config x seed) axis. Must run under ``enable_x64()`` so the clock
    arrays below really are int64. ``wl`` is the lowered
    ``WorkloadOperands`` struct (see ``repro.workloads.lower``) — every
    leaf is a traced operand and may vary per replica in the batched path,
    including the per-phase cost rows ``wl.cost_rows (P, 8)`` and the
    per-phase ALock budgets ``wl.b_init (P, 2)``. ``lat_samples`` sizes
    the latency ring (static; default ``LAT_SAMPLES`` — the ring-overflow
    tests shrink it to exercise wraparound cheaply).

    The Pallas backend (``repro.kernels.event_loop``) reproduces this loop
    bitwise; any semantic change here must be mirrored there (the
    equivalence tests will catch a divergence).
    """
    sem = init_sem(T, K)
    ready = jnp.zeros(T, I64)
    busy = jnp.zeros(N, I64)
    op_start = jnp.zeros(T, I64)
    done = jnp.zeros(T, I32)
    lat = jnp.full(lat_samples, -1, I64)
    lat_n = jnp.int32(0)
    key = jax.random.key(wl.seed)
    kpn = K // N
    never = jnp.iinfo(jnp.int64).max   # parked threads lose every argmin

    # static via the operand shape: single-phase workloads (every paper
    # figure, the whole SimConfig adapter path) skip the per-event phase
    # resolve / active-mask / rejoin machinery entirely. Sound because
    # lowering guarantees P == 1 operands are all-active (a masked single
    # phase is lowered as two identical halves).
    multi_phase = wl.edges.shape[0] > 1

    # static alg gates: the hierarchical cohort test and the read-draw
    # dispatch are python-dead for every other machine, so the existing
    # algorithms trace the exact pre-change program
    is_hl = alg == "hlock"
    is_rw = alg == "alock-rw"

    # static via the arr_fix shape: R == 0 is the closed loop and traces
    # the exact pre-traffic program (every `if open_loop` block below is
    # python-level dead code then — bitwise inertness by construction)
    R = wl.arr_fix.shape[-1]
    open_loop = R > 0
    if open_loop:
        # lazy: repro.traffic pulls in the i32pair helpers, and the import
        # is only needed on the open-loop path anyway
        from repro.traffic.metrics import COMPLETED, DROPPED, IN_SERVICE
        from repro.traffic.stream import arrival_plan, arrival_times_i64
        plan = arrival_plan(wl, n_events)
        arr = arrival_times_i64(plan.gaps)          # (R,) i64
        idx_r = jnp.arange(R, dtype=I32)

    def event(i, carry):
        if open_loop:
            (sem, ready, busy, op_start, done, lat, lat_n, nreacq, npass,
             rstat, curreq, arrptr, qlen, wq, soj) = carry
        else:
            sem, ready, busy, op_start, done, lat, lat_n, nreacq, npass \
                = carry
        if multi_phase:
            # piecewise phase over the event axis; with all-active phases
            # every line below reduces bitwise to the flat engine
            ph = jnp.sum(i >= wl.edges) - 1
            act = wl.active[ph]
            # phase boundary: a thread whose node rejoins resumes from the
            # cluster's current clock — not its stale park time — so a
            # down phase really costs it the interval (no deferred-event
            # catch-up). "now" is the next event time of the continuously-
            # active threads (a rejoiner's own parked clock must not drag
            # it backwards).
            was_act = wl.active[jnp.maximum(ph - 1, 0)]
            rejoin = jnp.any(i == wl.edges) & (act != 0) & (was_act == 0)
            cont_min = jnp.min(jnp.where((act != 0) & (was_act != 0),
                                         ready, never))
            now_min = jnp.where(cont_min == never,
                                jnp.min(jnp.where(act != 0, ready, never)),
                                cont_min)
            ready = jnp.where(rejoin, jnp.maximum(ready, now_min), ready)
            actm = act != 0
        else:
            ph = 0
            actm = None
        if open_loop:
            # idle threads (NCS, no request bound) wake at the earliest
            # available arrival instead of re-arming; busy threads keep
            # their own clocks. A drained stream with everyone idle makes
            # every lane read `never` -> the event is a no-op (live=False).
            pend = (sem.pc == mc.NCS) & (curreq < 0)
            avail = (rstat == 0) & (plan.tok == 1)
            next_arr = jnp.min(jnp.where(avail, arr, never))
            elig = jnp.where(pend, jnp.maximum(ready, next_arr), ready)
        else:
            elig = ready
        if actm is not None:
            tid = jnp.argmin(jnp.where(actm, elig, never)).astype(I32)
        else:
            tid = jnp.argmin(elig).astype(I32)
        # phase-indexed cost rows + ALock budgets (constant rows for a
        # single-phase spec — identical arithmetic to the flat engine)
        cst = wl.cost_rows[ph]
        c_local, c_poll, c_cs = cst[0], cst[1], cst[2]
        c_svc_r, c_svc_l, c_wire_r, c_wire_l = (cst[4], cst[5], cst[6],
                                                cst[7])
        b_init = wl.b_init[ph]
        now = elig[tid]            # == ready[tid] on the closed-loop path
        if is_rw:
            # the reader/writer coin rides the same counter stream as the
            # other draws (4-way split; state-independent, so the kernel
            # precomputes it identically)
            k1, k2, k3, k4 = jax.random.split(
                jax.random.fold_in(key, i), 4)
        else:
            k1, k2, k3 = jax.random.split(jax.random.fold_in(key, i), 3)
        # workload draw (used only when this step is the NCS re-arm);
        # dtypes pinned so enabling x64 does not change the draws
        mynode = thread_node[tid]
        go_local = (jax.random.uniform(k1, dtype=jnp.float32)
                    < wl.locality[ph, tid])
        other = (mynode + 1 +
                 jax.random.randint(k2, (), 0, max(N - 1, 1), dtype=I32)) % N
        node = jnp.where(go_local, mynode, other).astype(I32)
        u3 = jax.random.uniform(k3, dtype=jnp.float32)
        # inverse-CDF draw of the within-node lock (uniform when zipf_s=0);
        # clamp guards the cumsum's final float32 ulp falling short of 1.0
        off = jnp.minimum(jnp.sum(u3 >= wl.zcdf[ph]).astype(I32), kpn - 1)
        new_t = node * kpn + off
        if is_hl:
            # hierarchical cohort: LOCAL means same *rack*, not same node.
            # The trivial topology (rack = arange(N)) makes this bitwise
            # the flat test — hlock's regression anchor against alock.
            new_c = (wl.rack[node] != wl.rack[mynode]).astype(I32)
        else:
            new_c = (node != mynode).astype(I32)
        if is_rw:
            u4 = jax.random.uniform(k4, dtype=jnp.float32)
            new_r = (u4 < wl.read_frac[ph, tid]).astype(I32)
        else:
            new_r = None

        if open_loop:
            live = now != never
            pend_tid = pend[tid]
            # -- arrival ingestion: every request with arr <= now either
            # joins the wait queue or drops (token reject / queue full).
            # `rank` orders the token-admitted newcomers so tail drop is
            # exact when a burst overshoots the remaining queue room.
            cnt_now = jnp.where(
                live, jnp.sum((arr <= now).astype(I32), dtype=I32), arrptr)
            newly = (idx_r >= arrptr) & (idx_r < cnt_now)
            rank = plan.tokcum - plan.tokcum[arrptr]
            join = newly & (plan.tok == 1) & (rank < plan.qcap - qlen)
            rstat = jnp.where(newly & ~join, DROPPED, rstat)
            qlen = qlen + jnp.sum(join.astype(I32), dtype=I32)
            arrptr = cnt_now
            # -- dispatch: an idle selected thread takes the FIFO head --
            queued = (rstat == 0) & (idx_r < arrptr)
            head = jnp.min(jnp.where(queued, idx_r,
                                     jnp.iinfo(jnp.int32).max))
            do_disp = live & pend_tid & jnp.any(queued)
            hd = jnp.minimum(head, jnp.int32(R - 1))
            rstat = rstat.at[hd].set(
                jnp.where(do_disp, IN_SERVICE, rstat[hd]))
            curreq = curreq.at[tid].set(
                jnp.where(do_disp, hd, curreq[tid]))
            wq = wq.at[hd].set(jnp.where(do_disp, now - arr[hd], wq[hd]))
            qlen = qlen - do_disp.astype(I32)
            # an idle thread with nothing to take makes no machine step
            step_ok = live & (~pend_tid | do_disp)

        was_ncs_bound = (sem.pc[tid] == mc.REL_CAS) | (sem.pc[tid] == mc.PASS) \
            | (sem.pc[tid] == mc.SL_REL)
        if is_rw:
            # a reader's RD_REL decrement is its release — it completes an
            # acquisition exactly like a writer's REL_CAS/PASS
            was_ncs_bound = was_ncs_bound | (sem.pc[tid] == mc.RD_REL)
        pre_pc = sem.pc[tid]
        sem2, code, tnode = sem_step(alg, sem, tid, b_init, thread_node,
                                     lock_node, new_t, new_c, new_r,
                                     rack=wl.rack)
        finished = was_ncs_bound & (sem2.pc[tid] == mc.NCS)
        reacq = (pre_pc == mc.SPIN_BUDGET) & (sem2.pc[tid] == mc.SET_VICTIM_R)
        passed = pre_pc == mc.PASS
        if open_loop:
            sem2 = jax.tree_util.tree_map(
                lambda a, b: jnp.where(step_ok, a, b), sem2, sem)
            finished = finished & step_ok
            reacq = reacq & step_ok
            passed = passed & step_ok

        # completion accounting — lat_val reads op_start BEFORE this event's
        # re-stamp so it spans exactly acquire-entry -> release
        lat_val = now - op_start[tid]
        lat = lax.cond(
            finished,
            lambda l: l.at[lat_n % lat_samples].set(lat_val),
            lambda l: l, lat)
        lat_n = lat_n + finished.astype(I32)
        done = done.at[tid].add(finished.astype(I32))

        # cost application. node_mult degrades the node doing the work:
        # svc/wire belong to the target card's node, dt_plain to the
        # caller's CPU (mult 1.0 is bitwise inert — see _scale_cost)
        nm = wl.node_mult[ph]
        is_rdma = (code == OP_RDMA) | (code == OP_LOOP)
        if open_loop:
            is_rdma = is_rdma & step_ok
        svc = _scale_cost(jnp.where(code == OP_LOOP, c_svc_l, c_svc_r),
                          nm[tnode])
        wire = _scale_cost(jnp.where(code == OP_LOOP, c_wire_l, c_wire_r),
                           nm[tnode])
        start = jnp.maximum(now, busy[tnode])
        fin = start + svc
        busy = busy.at[tnode].set(jnp.where(is_rdma, fin, busy[tnode]))
        dt_plain = _scale_cost(jnp.select(
            [code == OP_LOCAL, code == OP_POLL, code == OP_CS,
             code == OP_THINK],
            [c_local, c_poll, c_cs, wl.think_ns[ph]], c_local), nm[mynode])
        new_ready = jnp.where(is_rdma, fin + wire, now + dt_plain)
        if open_loop:
            ready = ready.at[tid].set(
                jnp.where(step_ok, new_ready, ready[tid]))
            opst_upd = (pre_pc == mc.NCS) & step_ok
        else:
            ready = ready.at[tid].set(new_ready)
            opst_upd = pre_pc == mc.NCS
        # latency clock starts when the first lock op (SWAP/SL_CAS) can
        # issue, i.e. after the NCS think completes — Fig. 6 measures
        # acquire->release, not think_ns of app work
        op_start = op_start.at[tid].set(
            jnp.where(opst_upd, new_ready, op_start[tid]))
        nreacq = nreacq + reacq.astype(I32)
        npass = npass + passed.astype(I32)
        if open_loop:
            # -- departure: the finishing release frees the thread and
            # stamps the request's sojourn at the step's completion time
            req = curreq[tid]
            comp = finished & (req >= 0)
            rq = jnp.maximum(req, 0)
            soj = soj.at[rq].set(
                jnp.where(comp, new_ready - arr[rq], soj[rq]))
            rstat = rstat.at[rq].set(jnp.where(comp, COMPLETED, rstat[rq]))
            curreq = curreq.at[tid].set(jnp.where(comp, -1, curreq[tid]))
            return (sem2, ready, busy, op_start, done, lat, lat_n, nreacq,
                    npass, rstat, curreq, arrptr, qlen, wq, soj)
        return sem2, ready, busy, op_start, done, lat, lat_n, nreacq, npass

    carry = (sem, ready, busy, op_start, done, lat, lat_n, jnp.int32(0),
             jnp.int32(0))
    if open_loop:
        carry = carry + (jnp.zeros(R, I32), jnp.full(T, -1, I32),
                         jnp.int32(0), jnp.int32(0), jnp.full(R, -1, I64),
                         jnp.full(R, -1, I64))
        (sem, ready, busy, op_start, done, lat, lat_n, nreacq, npass,
         rstat, curreq, arrptr, qlen, wq,
         soj) = lax.fori_loop(0, n_events, event, carry)
        return (done, lat, lat_n, jnp.max(ready), nreacq, npass, arr, wq,
                soj, rstat)
    (sem, ready, busy, op_start, done, lat, lat_n, nreacq,
     npass) = lax.fori_loop(0, n_events, event, carry)
    return done, lat, lat_n, jnp.max(ready), nreacq, npass


_run_events_jit = functools.partial(
    jax.jit, static_argnames=("alg", "T", "N", "K", "n_events",
                              "lat_samples"))(_run_events)


def topology(alg: str, n_nodes: int, threads_per_node: int, n_locks: int,
             cm: CostModel = CostModel()):
    """Static per-shape operands: (thread_node, lock_node, cost scalars).

    thread_node/lock_node are fully determined by (alg, N, tpn, K) and
    stay unbatched broadcast operands of every engine. The cost scalars
    are ``cm.cost_rows(...)`` — the *default* rows; the engines actually
    consume the per-phase ``WorkloadOperands.cost_rows`` the lowering
    emits (which equals this tuple for every default-cost phase, keeping
    the pre-profile arithmetic bitwise-frozen).
    """
    T, N, K = n_nodes * threads_per_node, n_nodes, n_locks
    if N < 1 or K < 1:
        raise ValueError(f"need n_nodes >= 1 and n_locks >= 1, got "
                         f"(n_locks={K}, n_nodes={N})")
    if K % N != 0:
        # a real error, not an assert: benchmark CLIs feed user arguments
        # straight in here, and asserts vanish under `python -O`
        raise ValueError(
            f"locks must partition evenly across nodes: n_locks={K} is not "
            f"a multiple of n_nodes={N} (got (n_locks, n_nodes)=({K}, {N}))")
    thread_node = jnp.asarray([t // threads_per_node for t in range(T)], I32)
    lock_node = jnp.asarray([k // (K // N) for k in range(K)], I32)
    return thread_node, lock_node, cm.cost_rows(alg, N, threads_per_node)


def simulate(cfg: SimConfig | Workload, n_events: int = 400_000,
             cm: CostModel = CostModel(), backend: str = "auto") -> SimResult:
    """Run one workload (a ``Workload`` spec, or a legacy ``SimConfig``
    through the adapter) for ``n_events`` events on the chosen backend."""
    w = as_workload(cfg)
    lw = lower(w, n_events, cm)
    T, N, K = lw.n_threads, w.n_nodes, w.n_locks
    thread_node, lock_node, _ = topology(
        w.alg, N, w.threads_per_node, K, cm)
    backend = resolve_backend(backend)
    with enable_x64():
        if backend == "pallas":
            from repro.kernels.event_loop.ops import run_events_jit
            batched = WorkloadOperands(
                *(jnp.asarray(a)[None] for a in lw.operands))
            out = run_events_jit(
                w.alg, T, N, K, n_events, batched, thread_node, lock_node)
            out = tuple(o[0] for o in out)
        else:
            wl = WorkloadOperands(*(jnp.asarray(a) for a in lw.operands))
            out = _run_events_jit(
                w.alg, T, N, K, n_events, wl, thread_node, lock_node)
    done, lat, lat_n, t_end, nreacq, npass = out[:6]
    extras = {}
    if len(out) > 6:        # open-loop run: per-request serving arrays
        extras = dict(arr_ns=out[6], wait_ns=out[7], sojourn_ns=out[8],
                      rstat=out[9])
    ops = int(done.sum())
    sim_ns = max(int(t_end), 1)
    return SimResult(ops, sim_ns, ops / sim_ns * 1e3, lat, done,
                     int(nreacq), int(npass), **extras)

"""Explicit-state model checking of the lock machines.

Reproduces the paper's TLA+ verification (Appendix A) in-process:
  - MutualExclusion : no reachable state has two threads in CS
  - DeadlockFree    : every reachable non-quiescent state can progress
  - EventualEntry   : from every reachable state, every thread can still
                      reach its critical section (EF cs_t — livelock
                      freedom under a fair scheduler)

The machine's atomic actions are exactly the spec's labeled steps, so the
state space here corresponds to the PlusCal translation's.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

from repro.core import machine as mc


@dataclass
class CheckResult:
    states: int
    mutex_ok: bool
    deadlock_free: bool
    eventual_entry: bool
    violations: list = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return self.mutex_ok and self.deadlock_free and self.eventual_entry


def explore(machine: str, cohorts: tuple[int, ...],
            b_init: tuple[int, int] = (2, 2),
            max_states: int = 2_000_000) -> CheckResult:
    """BFS over all interleavings of `machine` with the given cohort
    assignment (one entry per thread: mc.LOCAL / mc.REMOTE)."""
    step = mc.MACHINES[machine]
    n = len(cohorts)
    init = mc.initial_state(n)
    seen: dict[mc.LockState, int] = {init: 0}
    order: list[mc.LockState] = [init]
    succs: list[list[int]] = []
    frontier = deque([init])
    mutex_ok = True
    violations = []

    while frontier:
        st = frontier.popleft()
        row = []
        ncs_count = sum(1 for t in range(n) if st.pc[t] == mc.NCS)
        cs_count = sum(1 for t in range(n) if st.pc[t] == mc.CS)
        if cs_count > 1:
            mutex_ok = False
            violations.append(("mutex", st))
        for t in range(n):
            nst, _ = step(st, t, cohorts[t], b_init)
            if nst not in seen:
                if len(seen) >= max_states:
                    raise RuntimeError(
                        f"state space exceeds {max_states}; shrink config")
                seen[nst] = len(order)
                order.append(nst)
                frontier.append(nst)
            row.append(seen[nst])
        succs.append(row)

    # deadlock: non-quiescent state whose every successor is itself
    deadlock_free = True
    for i, st in enumerate(order):
        if all(j == i for j in succs[i]):
            if any(st.pc[t] != mc.NCS for t in range(len(cohorts))):
                deadlock_free = False
                violations.append(("deadlock", st))

    # EF cs_t for every thread from every state: reverse reachability
    eventual = True
    nstates = len(order)
    radj: list[list[int]] = [[] for _ in range(nstates)]
    for i, row in enumerate(succs):
        for j in row:
            if j != i:
                radj[j].append(i)
    for t in range(len(cohorts)):
        good = [st.pc[t] == mc.CS for st in order]
        dq = deque(i for i, g in enumerate(good) if g)
        while dq:
            i = dq.popleft()
            for p in radj[i]:
                if not good[p]:
                    good[p] = True
                    dq.append(p)
        if not all(good):
            eventual = False
            bad = next(i for i, g in enumerate(good) if not g)
            violations.append(("eventual_entry", t, order[bad]))
    return CheckResult(len(order), mutex_ok, deadlock_free, eventual,
                       violations)


def bounded_overtaking(machine: str, cohorts: tuple[int, ...],
                       b_init: tuple[int, int], schedule,
                       steps: int = 20_000) -> int:
    """Run a schedule (iterable of tids); return the max number of CS
    entries that occur while some thread is continuously waiting. For the
    ALock this must be bounded by the budgets (fairness); the RDMA spinlock
    is unbounded (starvation-prone)."""
    step = mc.MACHINES[machine]
    st = mc.initial_state(len(cohorts))
    waiting_since: dict[int, int] = {}
    cs_entries = 0
    worst = 0
    for k, tid in zip(range(steps), schedule):
        was_cs = st.pc[tid] == mc.CS
        st, op = step(st, tid, cohorts[tid], b_init)
        if st.pc[tid] == mc.CS and not was_cs:
            cs_entries += 1
            waiting_since.pop(tid, None)
            for t0, since in waiting_since.items():
                worst = max(worst, cs_entries - since)
        if mc.wants_lock(st, tid) and tid not in waiting_since:
            waiting_since[tid] = cs_entries
    return worst

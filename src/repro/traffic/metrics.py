"""Host-side serving metrics: goodput, sojourn percentiles, knee detection.

The engines return four per-request arrays per replica (see
``docs/serving.md``):

  * ``arr``   — arrival time of each request slot (ns);
  * ``wq``    — queue wait (dispatch - arrival), ``-1`` if never dispatched;
  * ``soj``   — sojourn (departure - arrival), ``-1`` if never completed;
  * ``rstat`` — final slot status: 0 pending/queued, 1 in service,
    2 dropped (admission), 3 completed.

This module reduces them to the serving numbers the benchmarks emit and
checks rely on. Everything here is plain numpy over already-materialized
outputs — no tracing, no x64 dependence.

>>> import numpy as np
>>> s = serving_summary(np.int64([10, 20, 30, 40]),
...                     np.int64([0, 5, -1, -1]),
...                     np.int64([100, 105, -1, -1]),
...                     np.int32([COMPLETED, COMPLETED, DROPPED, PENDING]),
...                     t_end=1000)
>>> s["completed"], s["dropped"], s["drop_rate"]
(2, 1, 0.25)
>>> round(s["goodput_per_us"], 3)
2.0
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "COMPLETED", "DROPPED", "IN_SERVICE", "PENDING", "detect_knee",
    "serving_summary",
]

# request-slot status codes (mirrored by both engines)
PENDING, IN_SERVICE, DROPPED, COMPLETED = 0, 1, 2, 3


def serving_summary(arr, wq, soj, rstat, t_end: int) -> dict:
    """Reduce one replica's request arrays to serving aggregates.

    ``arrived`` counts slots whose arrival time falls inside the simulated
    window (the run is event-bounded, so late slots never materialize);
    conservation over that window — ``arrived == completed + dropped +
    in_service + queued`` — is asserted in ``tests/test_traffic.py``.
    ``goodput_per_us`` counts *completed* requests per simulated
    microsecond; ``offered_per_us`` counts arrivals the same way, so the
    two diverge exactly when the service saturates or drops.
    """
    arr = np.asarray(arr, np.int64)
    wq = np.asarray(wq, np.int64)
    soj = np.asarray(soj, np.int64)
    rstat = np.asarray(rstat)
    t_end = max(int(t_end), 1)
    inside = arr <= t_end
    arrived = int(inside.sum())
    completed = int((rstat == COMPLETED).sum())
    dropped = int(((rstat == DROPPED) & inside).sum())
    in_service = int((rstat == IN_SERVICE).sum())
    queued = arrived - completed - dropped - in_service
    csoj = soj[rstat == COMPLETED]
    cwq = wq[rstat == COMPLETED]
    t_us = t_end / 1e3
    return {
        "arrived": arrived,
        "completed": completed,
        "dropped": dropped,
        "in_service": in_service,
        "queued": queued,
        "drop_rate": dropped / arrived if arrived else 0.0,
        "offered_per_us": arrived / t_us,
        "goodput_per_us": completed / t_us,
        "p50_sojourn_ns": float(np.percentile(csoj, 50)) if csoj.size
        else float("nan"),
        "p99_sojourn_ns": float(np.percentile(csoj, 99)) if csoj.size
        else float("nan"),
        "mean_sojourn_ns": float(csoj.mean()) if csoj.size else float("nan"),
        "mean_wait_ns": float(cwq.mean()) if cwq.size else float("nan"),
        # time-average number in system over the window (Little's L):
        # each completed request contributes its full sojourn interval
        "mean_concurrency": float(csoj.sum()) / t_end,
    }


def detect_knee(offered, goodput, efficiency: float = 0.9):
    """Index of the saturation knee on an offered-load ramp.

    The knee is the first point whose achieved goodput falls below
    ``efficiency`` x offered — below it the service tracks the offered
    rate, above it queueing (or dropping) absorbs the difference.
    Returns ``None`` when the ramp never saturates.

    >>> detect_knee([1.0, 2.0, 4.0, 8.0], [1.0, 2.0, 3.9, 4.1])
    3
    >>> detect_knee([1.0, 2.0], [1.0, 2.0]) is None
    True
    """
    offered = np.asarray(offered, np.float64)
    goodput = np.asarray(goodput, np.float64)
    if offered.shape != goodput.shape or offered.ndim != 1:
        raise ValueError("offered/goodput must be matching 1-D sequences")
    sat = goodput < efficiency * offered
    return int(np.argmax(sat)) if sat.any() else None

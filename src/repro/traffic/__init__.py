"""Open-loop traffic engine: arrival streams, queueing, serving metrics.

``repro.traffic`` turns the closed-loop lock simulator into a lock
*service* under offered load: ``repro.workloads.Arrivals`` specs lower to
traced operands, :mod:`repro.traffic.stream` precomputes the per-request
arrival plan both engines consume, and :mod:`repro.traffic.metrics`
reduces the per-request outputs (arrival / wait / sojourn / status) to
goodput, latency percentiles, drop accounting and saturation knees.
See ``docs/serving.md`` for the model and ``benchmarks/serving_curves.py``
for the headline curves.
"""
from repro.traffic.metrics import (COMPLETED, DROPPED, IN_SERVICE, PENDING,
                                   detect_knee, serving_summary)
from repro.traffic.stream import (ArrivalPlan, arrival_gaps, arrival_plan,
                                  arrival_times_i64, arrival_times_pairs,
                                  per_request, request_phase_onehot,
                                  token_admit)

__all__ = [
    "ArrivalPlan", "COMPLETED", "DROPPED", "IN_SERVICE", "PENDING",
    "arrival_gaps", "arrival_plan", "arrival_times_i64",
    "arrival_times_pairs", "detect_knee", "per_request",
    "request_phase_onehot", "serving_summary", "token_admit",
]

"""Traced arrival-stream precompute shared by BOTH event-loop engines.

The open-loop extension (see ``docs/serving.md``) adds a request stream
on top of the closed-loop lock machines: requests arrive at traced times,
wait in a FIFO queue, get dispatched to the first idle thread, acquire /
release once and depart. Everything *state-independent* about the stream
is computed here, once, before the event loop runs:

  * **arrival gaps** — per-request inter-arrival times, the sum of a
    deterministic base gap (``arr_fix``, trace replay) and a Poisson
    jitter term drawn from the same counter-based ``fold_in`` stream as
    the event draws (counters offset past ``n_events`` so the two streams
    never collide);
  * **arrival times** — the prefix sum of the gaps, as int64 on the XLA /
    i64 path and as a carry-correct hi/lo i32 pair scan on the x64-off
    path (both are exact integer sums, so they agree bit for bit);
  * **token-bucket admission** — debit-on-arrival with per-request refill
    credit; state-independent (it depends only on arrival times), so it
    folds into a precomputed 0/1 admit mask;
  * **queue-bound rows** — the per-request queue capacity (a request's
    phase is its *index* interval via ``arr_edges``, mirroring the
    event-to-phase mapping).

The bounded-queue *tail drop* itself is service-dependent and stays in
the event loops; both consume the same plan arrays, which is what makes
the two engines (and both clock representations) bitwise-equal on the
arrival path — asserted end-to-end in ``tests/test_traffic.py``.

All helpers are pure ``jnp`` over f32/i32 with pinned dtypes: they trace
identically with and without x64 enabled.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.event_loop import i32pair as p32

I32 = jnp.int32
F32 = jnp.float32

__all__ = [
    "ArrivalPlan", "arrival_gaps", "arrival_plan", "arrival_times_i64",
    "arrival_times_pairs", "per_request", "request_phase_onehot",
    "token_admit",
]


class ArrivalPlan(NamedTuple):
    """State-independent per-request arrays (one replica, no batch axis)."""
    gaps: Any     # (R,) i32  inter-arrival gaps, ns
    tok: Any      # (R,) i32  1 = token-bucket admitted (1s when bucket off)
    tokcum: Any   # (R,) i32  exclusive prefix count of ``tok``
    qcap: Any     # (R,) i32  per-request wait-queue bound


def request_phase_onehot(arr_edges, n_requests: int):
    """(R, P) bool one-hot of each request's phase.

    Request ``k`` belongs to phase ``sum(k >= arr_edges) - 1`` — the exact
    analogue of the engines' per-event phase resolve, so padded phases
    (``arr_edges = INT32_MAX``) are unreachable by construction.
    """
    P = arr_edges.shape[0]
    idx = lax.broadcasted_iota(I32, (n_requests, P), 0)
    ph = jnp.sum((idx >= arr_edges[None, :]).astype(I32), axis=1,
                 dtype=I32) - 1
    return ph[:, None] == lax.broadcasted_iota(I32, (n_requests, P), 1)


def per_request(oh, vals):
    """Broadcast per-phase ``(P,)`` values onto requests via the one-hot
    ``(R, P)`` mask (exactly one True per row, so the sum is a gather)."""
    zero = jnp.zeros((), vals.dtype)
    return jnp.sum(jnp.where(oh, vals[None, :], zero), axis=1,
                   dtype=vals.dtype)


def arrival_gaps(seed, arr_fix, gap_ns_r, n_events: int):
    """Per-request inter-arrival gaps: base trace + Poisson jitter.

    ``gap_k = arr_fix[k] + round(-log(1 - u_k) * gap_ns_r[k])`` with
    ``u_k`` drawn from ``fold_in(key, n_events + 1 + k)`` — the same
    counter-based stream as the event draws, offset so the two never
    share a counter. ``gap_ns_r == 0`` (no Poisson term) contributes
    exactly 0, making trace replay deterministic.
    """
    R = arr_fix.shape[0]
    key = jax.random.key(seed)

    def draw(k):
        return jax.random.uniform(
            jax.random.fold_in(key, n_events + 1 + k), dtype=F32)

    u = jax.vmap(draw)(jnp.arange(R, dtype=I32))
    jit = jnp.round(-jnp.log1p(-u) * gap_ns_r).astype(I32)
    return arr_fix + jit


def arrival_times_i64(gaps):
    """Inclusive prefix sum of the gaps as int64 (requires x64)."""
    return jnp.cumsum(gaps.astype(jnp.int64))


def arrival_times_pairs(gaps):
    """Inclusive prefix sum as a hi/lo i32 pair — exact, x64-free.

    ``lax.associative_scan`` over the carry-correct pair add; integer
    addition is associative, so this agrees with the int64 cumsum bit
    for bit (and emits no ``scan`` primitive, keeping the pairs-trace
    primitive set frozen).
    """
    return lax.associative_scan(p32.padd, p32.from_i32(gaps))


def token_admit(gaps, rate_r, burst_r):
    """Debit-on-arrival token-bucket admission -> (R,) i32 0/1 mask.

    The bucket holds ``credit`` tokens (f32), starts full, refills at
    ``rate_r`` tokens/ns between arrivals and caps at ``burst_r``; a
    request is admitted iff a full token is available at its arrival
    (then debited). Rows with ``rate_r == 0`` switch the policy off
    (admit unconditionally). Admission depends only on arrival times —
    never on service — which is what lets it precompute to a mask.
    """

    def step(credit, x):
        g, r, b = x
        c = jnp.minimum(credit + g.astype(F32) * r, b)
        ok = c >= F32(1.0)
        return jnp.where(ok, c - F32(1.0), c), ok

    _, ok = lax.scan(step, burst_r[0], (gaps, rate_r, burst_r))
    return jnp.where(rate_r > F32(0.0), ok, True).astype(I32)


def arrival_plan(wl, n_events: int) -> ArrivalPlan:
    """Build the full per-request plan from lowered operands (one replica).

    ``wl`` is a ``WorkloadOperands`` with unbatched leaves; batched
    callers vmap this over the replica axis (the plan depends on the
    per-replica ``seed`` and per-phase arrival rows).
    """
    R = wl.arr_fix.shape[-1]
    oh = request_phase_onehot(wl.arr_edges, R)
    gap_ns_r = per_request(oh, wl.arr_gap_ns)
    rate_r = per_request(oh, wl.arr_token[:, 0])
    burst_r = per_request(oh, wl.arr_token[:, 1])
    qcap_r = per_request(oh, wl.arr_qcap)
    gaps = arrival_gaps(wl.seed, wl.arr_fix, gap_ns_r, n_events)
    tok = token_admit(gaps, rate_r, burst_r)
    tokcum = jnp.cumsum(tok, dtype=I32) - tok
    return ArrivalPlan(gaps=gaps, tok=tok, tokcum=tokcum, qcap=qcap_r)

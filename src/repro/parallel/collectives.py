"""Cohort collectives: the ALock insight applied to the TPU fabric.

The paper's asymmetric budgets amortize expensive-domain (RDMA) operations;
here the expensive domain is the cross-pod interconnect. Two step programs:

  local_accum_step — runs per pod (shard_map manual over 'pod'; data/model
      stay GSPMD-auto). Gradients accumulate into a pod-major buffer; the
      ONLY collectives are intra-pod (the "local cohort", cheap ICI).
  sync_step — every `remote_budget` microbatches: cross-pod mean of the
      accumulated grads + optimizer update (the "remote cohort" op). The
      cross-pod all-reduce runs on FSDP-sharded gradient shards, i.e. it is
      already the hierarchical reduce-scatter -> pod all-reduce ->
      all-gather schedule.

budget=1 recovers the exact synchronous baseline (every microbatch syncs);
budget=k divides the cross-pod collective term by k at the cost of k-step
gradient staleness across pods (local accumulation is exact within a pod).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.parallel.sharding import shard_map
from repro.train.optimizer import OptConfig, adamw_update


def make_budgeted_steps(cfg: ModelConfig, opt_cfg: OptConfig, mesh,
                        n_pod: int):
    """Returns (init_acc, local_accum_step, sync_step).

    Batches for local_accum_step carry a leading pod dim: tokens
    (n_pod, B/n_pod, S) sharded P('pod', 'data', None).
    """

    def per_pod(params, batch_pod):
        batch = {k: v[0] for k, v in batch_pod.items()}
        (loss, _), grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch), has_aux=True)(params)
        grads = jax.tree_util.tree_map(lambda g: g[None].astype(jnp.float32),
                                       grads)
        return grads, loss[None]

    sharded = shard_map(
        per_pod, mesh=mesh,
        in_specs=(P(), P("pod")),
        out_specs=(P("pod"), P("pod")),
        axis_names={"pod"}, check_vma=False)

    def init_acc(params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros((n_pod,) + p.shape, jnp.float32), params)

    def local_accum_step(params, acc, batch):
        grads, losses = sharded(params, batch)
        acc = jax.tree_util.tree_map(jnp.add, acc, grads)
        return acc, losses.mean()

    def sync_step(params, opt_state, acc, step, n_micro):
        # cross-pod cohort op: mean over the pod-major dim
        g = jax.tree_util.tree_map(
            lambda a: (a.mean(0) / n_micro).astype(jnp.float32), acc)
        params, opt_state, metrics = adamw_update(opt_cfg, params, g,
                                                  opt_state, step)
        acc = jax.tree_util.tree_map(jnp.zeros_like, acc)
        return params, opt_state, acc, metrics

    def sync_step_compressed(params, opt_state, acc, err, step, n_micro):
        """int8 cross-pod reduction with error feedback: the expensive-
        domain payload drops ~4x; each pod's quantization residual is
        carried into its next round (unbiased over time)."""
        from repro.parallel import compression as comp

        def qdq(a, e):
            g = a / n_micro + e                     # (n_pod, ...)

            def one(x):
                q, s = comp.quantize_int8(x)
                return comp.dequantize_int8(q, s, x.shape)
            deq = jax.vmap(one)(g)                  # per-pod payloads
            return deq, (g - deq).astype(jnp.float32)

        leaves_a, treedef = jax.tree_util.tree_flatten(acc)
        leaves_e = treedef.flatten_up_to(err)
        outs = [qdq(a, e) for a, e in zip(leaves_a, leaves_e)]
        deq = treedef.unflatten([o[0] for o in outs])
        new_err = treedef.unflatten([o[1] for o in outs])
        g = jax.tree_util.tree_map(
            lambda d: d.mean(0).astype(jnp.float32), deq)
        params, opt_state, metrics = adamw_update(opt_cfg, params, g,
                                                  opt_state, step)
        acc = jax.tree_util.tree_map(jnp.zeros_like, acc)
        return params, opt_state, acc, new_err, metrics

    return init_acc, local_accum_step, sync_step, sync_step_compressed


def hierarchical_mean(x, mesh):
    """Explicit two-level mean: reduce within pod ('data'), then across
    pods — the collective schedule the ALock hierarchy corresponds to."""
    def f(v):
        v = jax.lax.pmean(v, "data")
        return jax.lax.pmean(v, "pod")
    specs = P("pod", "data")
    return shard_map(f, mesh=mesh, in_specs=specs, out_specs=specs,
                     axis_names={"pod", "data"}, check_vma=False)(x)

"""Gradient compression for the expensive (cross-pod) domain.

int8 block-quantization with error feedback: the cross-pod sync step
reduces 4x fewer bytes; the quantization residual is carried into the next
accumulation round (error feedback keeps the scheme unbiased over time —
standard in production DP systems for DCN-class links).

Applies to the cohort-collective sync step: quantize the pod-local
accumulated gradient, mean the int8 payloads' dequantized values across
pods, keep (g - dequant(quant(g))) as the carried error.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32
BLOCK = 256


def _pad_len(n: int) -> int:
    return ((n + BLOCK - 1) // BLOCK) * BLOCK


def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8. Returns (q (N/BLOCK, BLOCK) i8,
    scales (N/BLOCK,) f32) over the flattened tensor."""
    flat = g.astype(F32).reshape(-1)
    n = flat.shape[0]
    pad = _pad_len(n) - n
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(F32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def compress_tree(grads):
    """tree -> (quantized tree of (q, scale), error tree)."""
    def one(g):
        q, s = quantize_int8(g)
        err = g.astype(F32) - dequantize_int8(q, s, g.shape)
        return (q, s), err
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    outs = [one(g) for g in leaves]
    qtree = treedef.unflatten([o[0] for o in outs])
    etree = treedef.unflatten([o[1] for o in outs])
    return qtree, etree


def decompress_tree(qtree, shapes_like):
    return jax.tree_util.tree_map(
        lambda qs, g: dequantize_int8(qs[0], qs[1], g.shape),
        qtree, shapes_like,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and not hasattr(x, "shape"))


def compressed_bytes(grads) -> int:
    """Payload bytes of the compressed representation (int8 + f32 scales)."""
    total = 0
    for g in jax.tree_util.tree_leaves(grads):
        n = _pad_len(g.size)
        total += n + (n // BLOCK) * 4
    return total

"""Mesh utilities for the sharded sweep path.

The one live export is :func:`shard_map` — the version-portable wrapper
``repro.core.batch`` uses to split a bucket's flattened replica axis over
a device mesh (axis name ``"data"``). The logical-axis rule tables,
divisibility-checked pspec derivation and audit log that used to live
here served the deleted model/serving stack and left with it; the sweep
path only ever needed plain ``P("data")`` specs.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

__all__ = ["shard_map"]


def shard_map(f, mesh: Mesh, in_specs, out_specs, axis_names,
              check_vma: bool = False):
    """Version-portable shard_map: the public ``jax.shard_map``
    (axis_names/check_vma kwargs) when this jax has it, else the
    ``jax.experimental.shard_map`` one (auto/check_rep kwargs —
    ``auto`` is the complement of ``axis_names``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=axis_names,
                             check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      auto=auto, check_rep=check_vma)

"""Logical-axis sharding: map named tensor axes onto mesh axes.

Every parameter / activation / cache tensor in the framework is annotated
with *logical* axis names ("embed", "ffn", "heads", ...). A ``Rules`` table
maps logical names to mesh axes (or tuples of mesh axes, or None). The
mapping is divisibility-checked per tensor: if a dimension does not divide
by the mesh-axis size the axis falls back to replication and the event is
recorded in an audit log (never a crash — GQA kv_heads < |model| is the
canonical case).

Train shapes use FSDP+TP rules (weight ``embed`` dims sharded on ``data``);
serve shapes use TP-only rules (weights replicated over ``data``, KV cache
sharded on batch/seq). See DESIGN.md §4.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Rules


@dataclass(frozen=True)
class Rules:
    """Mapping from logical axis name -> mesh axis (str), tuple of mesh axes,
    or None (replicated)."""

    table: Mapping[str, Any]

    def get(self, name: str | None):
        if name is None:
            return None
        return self.table.get(name, None)

    def override(self, **kw) -> "Rules":
        t = dict(self.table)
        t.update(kw)
        return Rules(t)


# Weight axes use FSDP ("data") on the embed dim + TP ("model") on the wide
# dim; activations shard batch on (pod, data).
TRAIN_RULES = Rules({
    # --- weights ---
    "embed": "data",          # FSDP / ZeRO-3 axis
    "ffn": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "vocab": "model",
    "experts": None,          # expert count rarely divides; shard expert_ffn
    "expert_ffn": "model",
    "layers": None,           # stacked-scan leading dim
    "mla_rank": None,
    "ssm_heads": "model",
    "ssm_inner": "model",
    "ssm_state": None,
    "conv": None,
    # --- activations ---
    "batch": ("pod", "data"),
    "seq": None,
    "act_vocab": "model",
    "act_embed": None,
    "act_heads": "model",
    "act_ffn": "model",
    "act_ssm_inner": "model",
    # --- caches (not used in train) ---
    "cache_batch": ("pod", "data"),
    "cache_seq": None,
    "cache_heads": "model",
})

# Serving: no FSDP (weights must not be re-gathered every decode step).
SERVE_RULES = TRAIN_RULES.override(embed=None)


def rules_for_shape(kind: str, *, kv_divisible: bool) -> Rules:
    """Resolved rules for a workload shape kind.

    kind: train | prefill | decode | long_decode
    kv_divisible: whether cfg.n_kv_heads divides the model axis — decides
      whether decode caches shard heads (preferred) or sequence.
    """
    if kind == "train":
        return TRAIN_RULES
    if kind == "prefill":
        # prefill is serving: TP-only weights, cache sharded like decode
        r = SERVE_RULES
    elif kind == "decode":
        r = SERVE_RULES
    elif kind == "long_decode":
        # global_batch=1: batch axes cannot shard; context-shard the cache
        r = SERVE_RULES.override(
            cache_batch=None, batch=None,
            cache_seq=("data", "model"), cache_heads=None,
        )
        return r
    else:
        raise ValueError(f"unknown shape kind {kind!r}")
    if not kv_divisible:
        # GQA with kv_heads < |model|: shard the cache on sequence instead.
        r = r.override(cache_heads=None, cache_seq="model")
    return r


# ---------------------------------------------------------------------------
# Mesh context + audit log

_ctx = threading.local()


def _get_mesh() -> Mesh | None:
    return getattr(_ctx, "mesh", None)


def _get_rules() -> Rules | None:
    return getattr(_ctx, "rules", None)


@contextlib.contextmanager
def sharding_ctx(mesh: Mesh | None, rules: Rules | None):
    """Install (mesh, rules) so model code can emit sharding constraints.

    With no context installed, ``constrain`` is a no-op — smoke tests and
    single-device examples run unchanged.
    """
    old = (_get_mesh(), _get_rules())
    _ctx.mesh, _ctx.rules = mesh, rules
    try:
        yield
    finally:
        _ctx.mesh, _ctx.rules = old


@dataclass
class AuditLog:
    """Records every divisibility fallback, for DESIGN/EXPERIMENTS tables."""
    events: list = field(default_factory=list)

    def note(self, what: str):
        if what not in self.events:
            self.events.append(what)


AUDIT = AuditLog()


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        s = 1
        for a in axis:
            s *= _axis_size(mesh, a)
        return s
    return mesh.shape[axis] if axis in mesh.shape else 1


def _present(mesh: Mesh, axis):
    """Drop mesh axes not present in this mesh (e.g. 'pod' on single-pod)."""
    if axis is None:
        return None
    if isinstance(axis, (tuple, list)):
        kept = tuple(a for a in axis if a in mesh.shape)
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]
    return axis if axis in mesh.shape else None


def pspec(shape: Sequence[int], axes: Sequence[str | None],
          rules: Rules, mesh: Mesh, *, tensor: str = "?") -> P:
    """Build a PartitionSpec for `shape` with logical `axes` under `rules`.

    Any dim whose size does not divide the mapped mesh-axis size falls back
    to replication (audited). Mesh axes may be consumed at most once per
    tensor; later conflicting dims replicate (audited).
    """
    assert len(shape) == len(axes), (shape, axes, tensor)
    used: set[str] = set()
    out = []
    for dim, name in zip(shape, axes):
        axis = _present(mesh, rules.get(name))
        if axis is None:
            out.append(None)
            continue
        flat = axis if isinstance(axis, tuple) else (axis,)
        if any(a in used for a in flat):
            AUDIT.note(f"{tensor}: axis {name}->{axis} already used; replicated")
            out.append(None)
            continue
        size = _axis_size(mesh, axis)
        if dim % size != 0:
            AUDIT.note(f"{tensor}: dim {name}={dim} !% mesh{axis}={size}; replicated")
            out.append(None)
            continue
        used.update(flat)
        out.append(axis)
    # PartitionSpec wants trailing Nones trimmed-or-not; both fine.
    return P(*out)


def named_sharding(shape, axes, rules, mesh, *, tensor="?") -> NamedSharding:
    return NamedSharding(mesh, pspec(shape, axes, rules, mesh, tensor=tensor))


def constrain(x: jax.Array, *axes: str | None) -> jax.Array:
    """Sharding constraint by logical axes; no-op outside sharding_ctx.

    Inside a partial-manual shard_map (e.g. the budgeted cohort steps are
    manual over 'pod'), constraints must be expressed on the ambient
    abstract mesh with the manual axes dropped.
    """
    mesh, rules = _get_mesh(), _get_rules()
    if mesh is None or rules is None:
        return x
    spec = pspec(x.shape, axes, rules, mesh, tensor="act")
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:
        am = None
    manual = set()
    if am is not None and getattr(am, "axis_types", None):
        manual = {n for n, t in zip(am.axis_names, am.axis_types)
                  if "Manual" in str(t)}
    if manual:
        cleaned = []
        for e in spec:
            if isinstance(e, tuple):
                kept = tuple(a for a in e if a not in manual)
                cleaned.append(kept if kept else None)
            else:
                cleaned.append(None if e in manual else e)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(am, P(*cleaned)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_map(f, mesh: Mesh, in_specs, out_specs, axis_names,
              check_vma: bool = False):
    """Version-portable shard_map: the public ``jax.shard_map``
    (axis_names/check_vma kwargs) when this jax has it, else the
    ``jax.experimental.shard_map`` one (auto/check_rep kwargs —
    ``auto`` is the complement of ``axis_names``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=axis_names,
                             check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      auto=auto, check_rep=check_vma)


def tree_pspecs(spec_tree, rules: Rules, mesh: Mesh):
    """Map a tree of ParamSpec (anything with .shape/.axes) to PartitionSpecs."""
    from repro.models.params import ParamSpec  # local import, avoid cycle

    def one(path, s):
        name = "/".join(str(p) for p in path)
        return pspec(s.shape, s.axes, rules, mesh, tensor=name)

    return jax.tree_util.tree_map_with_path(
        one, spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def tree_shardings(spec_tree, rules: Rules, mesh: Mesh):
    specs = tree_pspecs(spec_tree, rules, mesh)
    return jax.tree_util.tree_map(lambda p: NamedSharding(mesh, p), specs,
                                  is_leaf=lambda x: isinstance(x, P))

"""Known-bad fixture corpus: one minimal offender per rule family.

The analyzer's own regression suite. Each fixture is the *smallest*
program (or injected input) that commits exactly the hazard a rule family
exists to catch; ``run_corpus`` runs every family's rules against its
offender and returns the findings per family. A family whose offender
produces **zero** findings means the rule has gone blind — the
``--selftest`` CLI mode and ``tests/test_analysis.py`` both fail on that.

Offenders:

  * ``mosaic_offender`` — a real ``pallas_call`` trace whose kernel does
    int64 math (M001), a dynamic per-row scatter (M002) and a 1-D iota
    (M003): the exact three hazards PR 5 hand-audited out of the event
    kernel;
  * ``rack_offender`` — the hlock topology hazard: a rack-index operand
    held as int64 flowing into the kernel's same-rack tier compare
    (M001) — the exact widening the engine's i32-pinned ``rack`` operand
    exists to prevent;
  * ``x64_offender`` — a trace that manufactures an int64 on a path
    declared x64-off (X001);
  * ``weak_offender`` — a python scalar fed straight into a trace, leaving
    a weak_type operand aval (R001);
  * ``lazy_resolver`` — a ``resolve_representation`` look-alike that
    ignores ``REPRO_EVENT_CLOCKS`` (R002: the env no longer keys the jit
    cache);
  * ``bucket_offender`` — one sweep bucket holding two different abstract
    signatures (R003: a silent recompile per sweep);
  * ``corrupt_buffer_table`` — a VMEM byte table whose ``scr.victim`` row
    drifted from the kernel's real allocation (V001);
  * ``corrupt_open_buffer_table`` — the open-loop variant: the
    per-request dispatch scratch ``scr.curreq`` (only allocated when the
    bucket carries ``R > 0`` request slots) drifted, diffed against a
    real arrival-stream trace — proves V001 watches the traffic buffers
    too (V001).

>>> fams = run_corpus()
>>> sorted(fams) == ["mosaic-lowerability", "retrace-hazards",
...                  "vmem-consistency", "x64-cleanliness"]
True
>>> all(len(f) > 0 for f in fams.values())
True
>>> len({f.rule for fs in fams.values() for f in fs}) >= 4
True
"""
from __future__ import annotations

import functools

import numpy as np

from repro.analysis.entrypoints import Entrypoint
from repro.analysis.rules import (RULES, _stamp, check_bucket_signatures,
                                  check_env_resolution,
                                  check_vmem_consistency, run_rules)

__all__ = ["run_corpus", "mosaic_offender", "rack_offender",
           "x64_offender", "weak_offender", "lazy_resolver",
           "bucket_offender", "corrupt_buffer_table",
           "corrupt_open_buffer_table"]


def mosaic_offender() -> Entrypoint:
    """A pallas_call whose kernel does everything Mosaic rejects."""
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental import enable_x64
    from jax.experimental import pallas as pl

    def bad_kernel(x_ref, o_ref):
        v = x_ref[...]
        idx = v[0, 0]                                 # traced scalar
        ramp = lax.iota(jnp.int32, 8)                 # M003: 1-D iota
        wide = v.astype(jnp.int64) * 2                # M001: 64-bit aval
        scat = v.at[0, idx].set(ramp[0])              # M002: dyn scatter
        o_ref[...] = scat + wide.astype(jnp.int32)

    def call(x):
        return pl.pallas_call(
            bad_kernel,
            out_shape=jax.ShapeDtypeStruct((8, 8), jnp.int32),
            interpret=True)(x)

    with enable_x64():
        jx = jax.make_jaxpr(call)(np.zeros((8, 8), np.int32))
    return Entrypoint("corpus:mosaic-offender", "pallas-native", jx,
                      repr32=True, x64_off=False)


def rack_offender() -> Entrypoint:
    """A 64-bit rack index reaching the hlock tier compare.

    The engine pins the topology assignment to i32 at lowering
    (``WorkloadOperands.rack``); this fixture is the counterfactual — an
    un-pinned ``np.asarray(racks)`` widening to int64 under x64 and
    flowing into the kernel's same-rack comparison, which Mosaic cannot
    lower (no 64-bit vector registers → M001)."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    from jax.experimental import pallas as pl

    def bad_kernel(rack_ref, o_ref):
        rack = rack_ref[...].astype(jnp.int64)        # M001: wide rack ids
        same_rack = rack[:, :1] == rack               # the tier compare
        o_ref[...] = same_rack.astype(jnp.int32)

    def call(r):
        return pl.pallas_call(
            bad_kernel,
            out_shape=jax.ShapeDtypeStruct((1, 8), jnp.int32),
            interpret=True)(r)

    with enable_x64():
        jx = jax.make_jaxpr(call)(np.zeros((1, 8), np.int32))
    return Entrypoint("corpus:rack-offender", "pallas-native", jx,
                      repr32=True, x64_off=False)


def x64_offender() -> Entrypoint:
    """An int64 manufactured on a path that promised zero 64-bit avals."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    def leaky(x):
        # the classic leak: an unpinned sum widens under x64
        return jnp.sum(x.astype(jnp.int64))

    with enable_x64():
        jx = jax.make_jaxpr(leaky)(np.zeros(4, np.int32))
    return Entrypoint("corpus:x64-offender", "pallas-pairs", jx,
                      repr32=False, x64_off=True)


def weak_offender() -> Entrypoint:
    """A python scalar operand: its aval carries weak_type=True."""
    import jax
    jx = jax.make_jaxpr(lambda x: x * 2)(1.0)     # python float, not array
    return Entrypoint("corpus:weak-offender", "xla-batch", jx,
                      repr32=False, x64_off=False)


def lazy_resolver(representation: str, interpret: bool) -> str:
    """Ignores ``REPRO_EVENT_CLOCKS`` — the bug ``run_events_jit`` exists
    to prevent: the env read happens at trace time only, so a cached
    executable of the other representation would be silently reused."""
    return "i64" if interpret else "i32pair"


def bucket_offender() -> dict:
    """One sweep bucket, two abstract signatures: replica 2's locality
    leaked float64 (e.g. an un-pinned ``np.asarray``), so the jit cache
    sees a second signature and recompiles mid-sweep."""
    from repro.workloads import Workload, lower
    ops = lower(Workload("alock", 2, 2, 8, locality=0.9), 512).operands
    drifted = ops._replace(
        locality=np.asarray(ops.locality, np.float64))
    return {"corpus:('alock', 4, 2, 8, 512, 0)": [ops, drifted]}


def corrupt_buffer_table(**kw) -> dict:
    """``vmem.buffer_table`` with ``scr.victim`` silently drifted — the
    planner now budgets a buffer the kernel does not allocate."""
    from repro.kernels.event_loop import vmem
    table = dict(vmem.buffer_table(**kw))
    (shape, nbytes) = table["scr.victim"]
    table["scr.victim"] = ((shape[0], shape[1] + 1), nbytes)
    return table


def corrupt_open_buffer_table(**kw) -> dict:
    """The open-loop drift: ``scr.curreq`` — the per-thread current-request
    dispatch scratch the traffic engine added — silently grew a column.
    Only meaningful against an ``R > 0`` trace (the closed-loop table has
    no such row)."""
    from repro.kernels.event_loop import vmem
    table = dict(vmem.buffer_table(**kw))
    (shape, nbytes) = table["scr.curreq"]
    table["scr.curreq"] = ((shape[0], shape[1] + 1), nbytes)
    return table


@functools.lru_cache(maxsize=1)
def _pairs_entrypoint():
    """One real (tiny) pairs-path trace for the vmem fixture to corrupt."""
    from repro.analysis.entrypoints import trace_entrypoints
    eps = trace_entrypoints(scenarios=["node-churn"], n_events=256,
                            kinds=["pallas-pairs"])
    return eps[0]


@functools.lru_cache(maxsize=1)
def _open_pairs_entrypoint():
    """One real open-loop (R > 0) pairs-path trace — the arrival rows,
    per-request outputs and dispatch scratch are all bound."""
    from repro.analysis.entrypoints import trace_entrypoints
    eps = trace_entrypoints(scenarios=["burst-storm"], n_events=256,
                            kinds=["pallas-pairs"])
    assert eps and all(ep.meta["dims"]["R"] > 0 for ep in eps)
    return eps[0]


def run_corpus() -> dict:
    """Run each family's rules against its known-bad offender.

    Returns ``{family: [Finding, ...]}`` — every list must be non-empty
    for the analyzer to be considered alive (``--selftest`` gates on it).
    """
    out: dict = {}
    out["mosaic-lowerability"] = run_rules(
        [mosaic_offender(), rack_offender()],
        rules=["M001", "M002", "M003"])
    out["x64-cleanliness"] = run_rules([x64_offender()], rules=["X001"])
    retrace = run_rules([weak_offender()], rules=["R001"])
    retrace += _stamp(RULES["R002"], check_env_resolution(lazy_resolver))
    retrace += _stamp(RULES["R003"], check_bucket_signatures(
        lowered_by_bucket=bucket_offender()))
    out["retrace-hazards"] = retrace
    out["vmem-consistency"] = _stamp(RULES["V001"], check_vmem_consistency(
        _pairs_entrypoint(), table_fn=corrupt_buffer_table))
    out["vmem-consistency"] += _stamp(
        RULES["V001"], check_vmem_consistency(
            _open_pairs_entrypoint(), table_fn=corrupt_open_buffer_table))
    return out

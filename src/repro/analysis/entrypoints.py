"""Trace the engine's public entrypoints to closed jaxprs — no execution.

The analyzer's raw material: every workload spec a registered simulator
scenario sweeps (``repro.experiments.scenario_workloads``) is lowered and
bucketed exactly like ``batch.sweep`` buckets it (shape key + phase
padding), and each bucket is traced through the public engine entrypoints
with ``jax.make_jaxpr`` — abstract evaluation only, nothing compiles,
nothing dispatches, no TPU required:

  ============== ==========================================================
  kind           what is traced
  ============== ==========================================================
  xla-batch      ``batch._run_events_batch`` (the vmapped XLA oracle),
                 under x64 — int64 clocks are that path's contract
  pallas-i64     ``ops.run_events`` with ``representation="i64"`` in
                 interpret mode (the CPU fast path), under x64
  pallas-native  ``ops.run_events`` with ``representation="i32pair"`` and
                 ``interpret=False`` — the kernel exactly as Mosaic would
                 receive it (tracing never invokes Mosaic)
  pallas-pairs   ``ops.run_events_pairs`` with x64 **disabled** — the
                 zero-int64 contract the x64-off CI leg runs
  ============== ==========================================================

``pallas-native`` and ``pallas-pairs`` entrypoints carry ``repr32=True``
(the Mosaic-lowerability family applies) and their ``meta`` records the
VMEM plan + static dims the vmem-consistency rule diffs the byte table
against. Tracing runs under an explicit x64 context per row, so the
catalog is identical whether the host process enables x64 or not.

Open-loop buckets ride in through the same four kinds: a scenario whose
workloads carry :class:`~repro.workloads.Arrivals` lowers to a shape key
with ``R > 0`` request slots, so the traced jaxprs include the arrival
ingestion/dispatch lanes and the per-request outputs, and the rules lint
them exactly like the closed loop. ``meta["dims"]["R"]`` /
``meta["open_loop"]`` mark those rows; the vmem-consistency rule prices
the open-loop buffer table through the same ``R``.

>>> eps = trace_entrypoints(scenarios=["node-churn"], n_events=512)
>>> sorted({ep.kind for ep in eps})
['pallas-i64', 'pallas-native', 'pallas-pairs', 'xla-batch']
>>> pairs = [ep for ep in eps if ep.kind == "pallas-pairs"]
>>> pairs[0].x64_off and pairs[0].repr32
True
>>> pairs[0].meta["plan"].representation
'i32pair'
>>> ramp = trace_entrypoints(scenarios=["burst-storm"], n_events=512,
...                          kinds=["xla-batch"])
>>> all(ep.meta["open_loop"] and ep.meta["dims"]["R"] > 0 for ep in ramp)
True
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Iterable

import numpy as np

__all__ = ["Entrypoint", "trace_entrypoints", "collect_buckets"]

#: trace length: shapes only (the event loop traces once regardless), so
#: small keeps the operand avals cheap while every phase program stays
#: strictly increasing
DEFAULT_TRACE_EVENTS = 2048


@dataclass(frozen=True)
class Entrypoint:
    """One traced engine entrypoint: a closed jaxpr + rule context."""
    name: str            # e.g. "pallas-pairs:('alock', 16, 4, 16, 2048, 0)"
    kind: str            # xla-batch | pallas-i64 | pallas-native | ...
    jaxpr: Any           # jax.core.ClosedJaxpr
    repr32: bool         # Mosaic-lowerability rules apply
    x64_off: bool        # x64-cleanliness rule applies
    meta: dict = field(default_factory=dict, compare=False)


def collect_buckets(scenarios: Iterable[str] | None = None,
                    n_events: int = DEFAULT_TRACE_EVENTS) -> dict:
    """Lower + bucket every scenario workload the way ``sweep`` would.

    Returns ``{shape_key: (batched WorkloadOperands, meta)}`` — one entry
    per distinct compile bucket across the selected scenarios (default:
    all registered simulator scenarios), each replica phase-padded to its
    bucket max so the batched leaves stack. ``meta`` records which
    scenarios contributed.
    """
    from repro.experiments import scenario_names, scenario_workloads
    from repro.workloads import WorkloadOperands, lower, pad_phases
    names = list(scenarios) if scenarios is not None else scenario_names()
    per_key: dict = {}
    sources: dict = {}
    for scen in names:
        wls = scenario_workloads(scen)
        if not wls:
            continue
        for w in wls:
            lw = lower(w, n_events)
            per_key.setdefault(lw.shape_key, []).append(lw.operands)
            sources.setdefault(lw.shape_key, set()).add(scen)
    buckets = {}
    for key, ops in per_key.items():
        pmax = max(o.n_phases for o in ops)
        padded = [pad_phases(o, pmax) for o in ops]
        wl = WorkloadOperands(*(np.stack([np.asarray(getattr(o, f))
                                          for o in padded])
                                for f in WorkloadOperands._fields))
        buckets[key] = (wl, {"scenarios": sorted(sources[key]),
                             "n_phases": pmax})
    return buckets


def _trace(fn, *args):
    import jax
    return jax.make_jaxpr(fn)(*args)


def trace_entrypoints(scenarios: Iterable[str] | None = None,
                      n_events: int = DEFAULT_TRACE_EVENTS,
                      kinds: Iterable[str] | None = None
                      ) -> list[Entrypoint]:
    """Build the full traced-entrypoint catalog for the rule engine.

    One entrypoint per (bucket, kind); ``kinds`` filters (default: all
    four). Tracing is abstract evaluation only — no executable is built,
    no kernel runs, and the process-wide x64 flag is saved/restored.
    """
    import jax
    from jax.experimental import disable_x64, enable_x64
    from repro.core.batch import _run_events_batch
    from repro.core.sim import LAT_SAMPLES, topology
    from repro.kernels.event_loop import ops as el_ops
    from repro.workloads import WorkloadOperands

    want = set(kinds) if kinds is not None else {
        "xla-batch", "pallas-i64", "pallas-native", "pallas-pairs"}
    eps: list[Entrypoint] = []
    for key, (wl, bmeta) in collect_buckets(scenarios, n_events).items():
        alg, T, N, K, ne, R = key
        B, P = wl.seed.shape[0], bmeta["n_phases"]
        thread_node, lock_node, _ = topology(alg, N, T // N, K)
        # hl/rw ride in dims so the vmem rule prices the alg-gated buffers
        # (rack row, read coin/probability rows, reader-count scratch)
        dims = {"T": T, "N": N, "K": K, "P": P, "R": R,
                "hl": alg == "hlock", "rw": alg == "alock-rw"}
        meta = dict(bmeta, shape_key=key, B=B, dims=dims,
                    open_loop=R > 0)

        def j(a):
            return jax.numpy.asarray(a)

        wlj = WorkloadOperands(*(j(a) for a in wl))
        tn, ln = j(thread_node), j(lock_node)

        if "xla-batch" in want:
            with enable_x64():
                jx = _trace(functools.partial(
                    _run_events_batch, alg, T, N, K, ne), wlj, tn, ln)
            eps.append(Entrypoint(f"xla-batch:{key}", "xla-batch", jx,
                                  repr32=False, x64_off=False, meta=meta))
        if "pallas-i64" in want:
            with enable_x64():
                jx = _trace(functools.partial(
                    el_ops.run_events, alg, T, N, K, ne, interpret=True,
                    representation="i64"), wlj, tn, ln)
            eps.append(Entrypoint(f"pallas-i64:{key}", "pallas-i64", jx,
                                  repr32=False, x64_off=False, meta=meta))
        # the native rows re-plan exactly like run_events will (single
        # clamping+planning code path), so the vmem rule diffs the same
        # (tile, ev_chunk) the traced pallas_call actually bound
        if "pallas-native" in want:
            plan = el_ops.plan_for_run(B, P, ne, T, N, K, R=R,
                                       hl=dims["hl"], rw=dims["rw"],
                                       interpret=False,
                                       representation="i32pair")
            with enable_x64():
                jx = _trace(functools.partial(
                    el_ops.run_events, alg, T, N, K, ne, interpret=False,
                    representation="i32pair"), wlj, tn, ln)
            eps.append(Entrypoint(f"pallas-native:{key}", "pallas-native",
                                  jx, repr32=True, x64_off=False,
                                  meta=dict(meta, plan=plan)))
        if "pallas-pairs" in want:
            plan = el_ops.plan_for_run(B, P, ne, T, N, K, R=R,
                                       hl=dims["hl"], rw=dims["rw"],
                                       interpret=False,
                                       representation="i32pair")
            with disable_x64():
                jx = _trace(functools.partial(
                    el_ops.run_events_pairs, alg, T, N, K, ne,
                    interpret=False), wlj, tn, ln)
            eps.append(Entrypoint(f"pallas-pairs:{key}", "pallas-pairs",
                                  jx, repr32=True, x64_off=True,
                                  meta=dict(meta, plan=plan,
                                            lat_samples=LAT_SAMPLES)))
    return eps

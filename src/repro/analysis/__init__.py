"""Static jaxpr lint for the event-engine: trace, don't run.

``repro.analysis`` traces the engine's public entrypoints to closed
jaxprs (``jax.make_jaxpr`` — abstract evaluation, nothing executes, no
TPU needed) and evaluates a rule registry over the equation graphs.
Four rule families ship (see ``docs/analysis.md`` for the catalog):

  * **mosaic-lowerability** (M001-M003) — the native-representation
    kernel must stay free of 64-bit avals, dynamic scatter/gather and
    1-D iota, the three things Mosaic rejects;
  * **x64-cleanliness** (X001) — the pairs path holds a zero-int64
    contract with x64 disabled;
  * **retrace-hazards** (R001-R003) — weak_type operands, lazily-read
    env statics, >1 abstract signature per sweep bucket;
  * **vmem-consistency** (V001) — ``vmem.buffer_table`` must mirror the
    buffers the traced ``pallas_call`` actually binds.

CLI: ``python -m repro.analysis`` (report), ``--strict`` (exit 1 on any
finding — the CI lint leg), ``--selftest`` (run the known-bad fixture
corpus), ``--imports`` (import-graph dead-weight report).

>>> from repro.analysis import Finding, RULES
>>> sorted(RULES)
['M001', 'M002', 'M003', 'R001', 'R002', 'R003', 'V001', 'X001']
>>> print(Finding("M001", "mosaic-lowerability", "error",
...               "pallas-native:demo", "pallas_call @ k.py:1",
...               "int64 aval inside the kernel").format())
M001 (mosaic-lowerability, error) pallas-native:demo [pallas_call @ k.py:1]
      int64 aval inside the kernel
"""
from repro.analysis.entrypoints import (Entrypoint, collect_buckets,
                                        trace_entrypoints)
from repro.analysis.rules import (RULES, Finding, Rule, bucket_signature,
                                  check_bucket_signatures,
                                  check_env_resolution,
                                  check_runner_cache_keys,
                                  check_vmem_consistency, rule, run_rules)
from repro.analysis.walk import EqnSite, all_avals, eqn_src, walk_jaxpr

__all__ = [
    "Entrypoint", "trace_entrypoints", "collect_buckets",
    "Finding", "Rule", "RULES", "rule", "run_rules",
    "bucket_signature", "check_bucket_signatures", "check_env_resolution",
    "check_runner_cache_keys", "check_vmem_consistency",
    "EqnSite", "walk_jaxpr", "all_avals", "eqn_src",
]

"""CLI for the static jaxpr lint: ``python -m repro.analysis``.

Modes (mutually exclusive; default is a lint report):

  (default)    trace the entrypoint catalog, run every rule, print the
               findings; exit 0 regardless
  --strict     same, but exit 1 when any finding fires (the CI lint leg)
  --selftest   run the known-bad fixture corpus and verify every rule
               family still fires (>= 4 distinct rule ids, all 4
               families); exit 1 when a family has gone blind
  --imports    static import-graph gate: every src/repro module no entry
               package can reach must carry an explicit quarantine entry
               (exit 1 on unexpected unreachables or stale quarantines)

Scoping/output knobs: ``--scenarios a,b`` restricts tracing to named
scenarios, ``--events N`` sets the traced event-count (shapes only),
``--rules M001,X001`` restricts the rule set, ``--json PATH`` writes
machine-readable findings.
"""
from __future__ import annotations

import argparse
import json
import sys


def _lint(args) -> int:
    from repro.analysis.entrypoints import trace_entrypoints
    from repro.analysis.rules import RULES, run_rules
    scenarios = args.scenarios.split(",") if args.scenarios else None
    rules = args.rules.split(",") if args.rules else None
    unknown = set(rules or ()) - set(RULES)
    if unknown:
        print(f"unknown rule ids: {', '.join(sorted(unknown))} "
              f"(known: {', '.join(sorted(RULES))})", file=sys.stderr)
        return 2
    eps = trace_entrypoints(scenarios=scenarios, n_events=args.events)
    findings = run_rules(eps, rules=rules)
    print(f"traced {len(eps)} entrypoints "
          f"({len({e.meta.get('shape_key') for e in eps})} compile "
          f"buckets); {len(RULES) if rules is None else len(rules)} rules; "
          f"{len(findings)} finding(s)")
    for f in findings:
        print(f.format())
    if args.json:
        with open(args.json, "w", encoding="utf-8") as fh:
            json.dump([vars(f) for f in findings], fh, indent=2)
        print(f"wrote {args.json}")
    if findings:
        return 1 if args.strict else 0
    print("lint-clean.")
    return 0


def _selftest(args) -> int:
    from repro.analysis.fixtures import run_corpus
    from repro.analysis.rules import RULES
    per_family = run_corpus()
    fired = {f.rule for fs in per_family.values() for f in fs}
    ok = True
    for family, fs in sorted(per_family.items()):
        ids = sorted({f.rule for f in fs})
        status = "ok" if fs else "BLIND"
        ok &= bool(fs)
        print(f"{family:22s} {status:6s} "
              f"({len(fs)} finding(s): {', '.join(ids) or '-'})")
    families = {RULES[r].family for r in fired}
    print(f"corpus: {len(fired)} distinct rule ids across "
          f"{len(families)} families")
    if len(fired) < 4 or len(families) < 4:
        print("selftest FAILED: need >= 4 rule ids across all 4 families",
              file=sys.stderr)
        return 1
    if not ok:
        print("selftest FAILED: a rule family no longer flags its "
              "known-bad fixture", file=sys.stderr)
        return 1
    print("selftest passed.")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static jaxpr lint over the engine's traced "
                    "entrypoints (no execution)")
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--strict", action="store_true",
                      help="exit 1 when any finding fires")
    mode.add_argument("--selftest", action="store_true",
                      help="run the known-bad fixture corpus")
    mode.add_argument("--imports", action="store_true",
                      help="import-graph gate (quarantine-checked dead "
                           "weight; exit 1 on drift)")
    ap.add_argument("--scenarios", default="",
                    help="comma-separated scenario names (default: all)")
    ap.add_argument("--events", type=int, default=None,
                    help="traced event count (shapes only; default 2048)")
    ap.add_argument("--rules", default="",
                    help="comma-separated rule ids (default: all)")
    ap.add_argument("--json", default="",
                    help="write findings as JSON to this path")
    args = ap.parse_args(argv)
    if args.events is None:
        from repro.analysis.entrypoints import DEFAULT_TRACE_EVENTS
        args.events = DEFAULT_TRACE_EVENTS
    if args.imports:
        from repro.analysis.imports import report
        text, rc = report()
        print(text)
        return rc
    if args.selftest:
        return _selftest(args)
    return _lint(args)


if __name__ == "__main__":
    sys.exit(main())

"""Rule registry + the four shipped rule families.

A *rule* inspects traced entrypoints (``repro.analysis.entrypoints``) —
closed jaxprs obtained **without executing** anything — and emits
structured :class:`Finding`\\ s. Rules come in two scopes:

  * ``entrypoint`` — run once per traced entrypoint (most rules);
  * ``global`` — run once per analysis over python-level invariants that
    are not a property of any single jaxpr (jit cache keys, bucket
    signatures across a whole scenario sweep).

Shipped families (rule ids are stable — baselines and CI grep them):

  ============ ======== ====================================================
  family       rules    catches
  ============ ======== ====================================================
  mosaic-      M001     64-bit avals inside a native-representation kernel
  lowerability M002     dynamic scatter/gather inside the kernel
               M003     1-D iota inside the kernel (Mosaic requires >= 2D)
  x64-         X001     any 64-bit aval in the x64-off pairs path
  cleanliness
  retrace-     R001     weak_type leaking into traced entrypoint operands
  hazards      R002     env-keyed static args resolved lazily (jit cache)
               R003     >1 abstract signature per sweep bucket (recompiles)
  vmem-        V001     ``vmem.py`` byte-table drift vs the kernel's actual
  consistency           pallas_call buffers
  ============ ======== ====================================================

Adding a rule (see ``docs/analysis.md``): write a check function returning
a list of findings and decorate it —

>>> from repro.analysis.rules import RULES, rule
>>> @rule("T900", family="demo", severity="error",
...       summary="never fires (docs example)")
... def _demo(ep):
...     return []
>>> RULES["T900"].family
'demo'
>>> _ = RULES.pop("T900")      # keep the registry clean after the demo

``run_rules`` drives every registered rule over a list of entrypoints and
returns the combined findings (empty list == lint-clean).
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field, replace
from typing import Callable, Iterable

import numpy as np

from repro.analysis.walk import all_avals, walk_jaxpr

__all__ = ["Finding", "Rule", "RULES", "rule", "run_rules",
           "check_env_resolution", "check_runner_cache_keys",
           "check_bucket_signatures", "check_vmem_consistency",
           "bucket_signature"]

#: scatter/gather primitive names Mosaic cannot lower against VMEM state
#: (the kernel re-expresses them as masked one-hot selects)
DYNAMIC_MEMORY_PRIMS = frozenset({
    "scatter", "scatter-add", "scatter-mul", "scatter-min", "scatter-max",
    "gather",
})


@dataclass(frozen=True)
class Finding:
    """One structured lint hit: what fired, where, and how to fix it."""
    rule: str            # stable id, e.g. "M001"
    family: str          # rule family, e.g. "mosaic-lowerability"
    severity: str        # "error" | "warning"
    entrypoint: str      # traced entrypoint name (or "<global>")
    where: str           # eqn provenance: path + file:line
    message: str
    hint: str = ""

    def format(self) -> str:
        loc = f" [{self.where}]" if self.where else ""
        tail = f"\n      hint: {self.hint}" if self.hint else ""
        return (f"{self.rule} ({self.family}, {self.severity}) "
                f"{self.entrypoint}{loc}\n      {self.message}{tail}")


@dataclass(frozen=True)
class Rule:
    id: str
    family: str
    severity: str
    summary: str
    scope: str                       # "entrypoint" | "global"
    check: Callable = field(compare=False)


RULES: dict[str, Rule] = {}


def rule(id: str, *, family: str, severity: str = "error",
         summary: str = "", scope: str = "entrypoint"):
    """Register a check function under a stable rule id.

    ``scope="entrypoint"`` checks are called as ``check(ep)`` per traced
    entrypoint; ``scope="global"`` checks are called once as
    ``check(entrypoints)``. Both return an iterable of findings (the
    decorator stamps ``rule``/``family``/``severity`` onto any finding
    the check left blank, so checks can just describe the defect).
    """
    if scope not in ("entrypoint", "global"):
        raise ValueError(f"scope must be 'entrypoint' or 'global', "
                         f"got {scope!r}")

    def deco(fn):
        if id in RULES:
            raise ValueError(f"rule {id!r} already registered")
        RULES[id] = Rule(id, family, severity, summary, scope, fn)
        return fn
    return deco


def _stamp(r: Rule, findings: Iterable[Finding]) -> list[Finding]:
    out = []
    for f in findings:
        if not f.rule:
            f = replace(f, rule=r.id, family=r.family, severity=r.severity)
        out.append(f)
    return out


def run_rules(entrypoints, rules: Iterable[str] | None = None
              ) -> list[Finding]:
    """Run the selected rules (default: all) over the traced entrypoints.

    Returns every finding, entrypoint-scoped rules first (in entrypoint
    order), then global rules. An empty list means lint-clean.
    """
    eps = list(entrypoints)
    active = [RULES[i] for i in rules] if rules is not None \
        else list(RULES.values())
    findings: list[Finding] = []
    for r in active:
        if r.scope != "entrypoint":
            continue
        for ep in eps:
            findings += _stamp(r, r.check(ep))
    for r in active:
        if r.scope == "global":
            findings += _stamp(r, r.check(eps))
    return findings


def _wide(dtype) -> bool:
    """True for 64-bit *numeric* dtypes (extended dtypes — PRNG keys —
    are opaque and skipped)."""
    import jax
    if dtype is None:
        return False
    try:
        if jax.dtypes.issubdtype(dtype, jax.dtypes.extended):
            return False
        return np.dtype(dtype).itemsize == 8
    except TypeError:
        return False


def _f(ep_name, where, message, hint="") -> Finding:
    return Finding("", "", "", ep_name, where, message, hint)


# ---------------------------------------------------------------------------
# mosaic-lowerability: applies to entrypoints targeting the native TPU
# kernel (repr32 — Mosaic has no 64-bit vector registers, rejects dynamic
# scatters against VMEM state, and requires >= 2D iota)


@rule("M001", family="mosaic-lowerability",
      summary="64-bit aval inside the native-representation kernel")
def _kernel_wide_dtype(ep):
    if not ep.repr32:
        return []
    out, seen = [], set()
    for site in walk_jaxpr(ep.jaxpr):
        if not site.in_kernel:
            continue
        for v in list(site.eqn.invars) + list(site.eqn.outvars):
            aval = getattr(v, "aval", None)
            dt = getattr(aval, "dtype", None)
            key = (site.path, site.eqn.primitive.name, str(dt))
            if _wide(dt) and key not in seen:
                seen.add(key)
                out.append(_f(
                    ep.name, f"{site.path} @ {site.src}",
                    f"{dt} aval on `{site.eqn.primitive.name}` inside the "
                    f"kernel jaxpr — Mosaic has no 64-bit vectors",
                    "hold clocks as hi/lo i32 pairs "
                    "(kernels/event_loop/i32pair.py)"))
    return out


@rule("M002", family="mosaic-lowerability",
      summary="dynamic scatter/gather inside the kernel")
def _kernel_dynamic_scatter(ep):
    if not ep.repr32:
        return []
    out = []
    for site in walk_jaxpr(ep.jaxpr):
        if site.in_kernel and site.eqn.primitive.name in DYNAMIC_MEMORY_PRIMS:
            out.append(_f(
                ep.name, f"{site.path} @ {site.src}",
                f"`{site.eqn.primitive.name}` inside the kernel jaxpr — "
                f"Mosaic rejects per-row dynamic scatter/gather against "
                f"VMEM state",
                "re-express as a masked one-hot select over the indexed "
                "axis (see the latency-ring accumulate in "
                "kernels/event_loop/kernel.py)"))
    return out


@rule("M003", family="mosaic-lowerability",
      summary="1-D iota inside the kernel")
def _kernel_1d_iota(ep):
    if not ep.repr32:
        return []
    out = []
    for site in walk_jaxpr(ep.jaxpr):
        if (site.in_kernel and site.eqn.primitive.name == "iota"
                and len(site.eqn.params.get("shape", (0, 0))) < 2):
            out.append(_f(
                ep.name, f"{site.path} @ {site.src}",
                f"1-D iota (shape {site.eqn.params.get('shape')}) inside "
                f"the kernel jaxpr — Mosaic requires >= 2D iota",
                "use lax.broadcasted_iota with a 2D shape (the kernel's "
                "_iota helper)"))
    return out


# ---------------------------------------------------------------------------
# x64-cleanliness: the pairs path must run with x64 entirely off — a single
# 64-bit aval anywhere in the trace means some dtype was left unpinned


@rule("X001", family="x64-cleanliness",
      summary="64-bit aval in the x64-off pairs path")
def _x64_clean(ep):
    if not ep.x64_off:
        return []
    out, seen = [], set()
    for aval, where in all_avals(ep.jaxpr):
        dt = getattr(aval, "dtype", None)
        if _wide(dt) and where not in seen:
            seen.add(where)
            out.append(_f(
                ep.name, where,
                f"{dt} aval on the x64-off pairs path — run_events_pairs "
                f"must never touch a 64-bit value",
                "pin the dtype at the producing op (jnp.int32/float32) or "
                "route the quantity through i32pair"))
    return out


# ---------------------------------------------------------------------------
# retrace-hazards


@rule("R001", family="retrace-hazards",
      summary="weak_type leaking into traced entrypoint operands")
def _weak_operands(ep):
    out = []
    jaxpr = getattr(ep.jaxpr, "jaxpr", ep.jaxpr)
    consts = getattr(ep.jaxpr, "consts", [])
    for i, v in enumerate(jaxpr.invars):
        if getattr(v.aval, "weak_type", False):
            out.append(_f(
                ep.name, f"operand {i}",
                f"traced operand {i} has a weak_type aval "
                f"({v.aval.dtype}) — python scalars fed straight into the "
                f"trace retrace on every dtype-context change",
                "jnp.asarray(..., dtype) the operand before the jit "
                "boundary"))
    for i, c in enumerate(consts):
        aval = getattr(c, "aval", None)
        if getattr(aval, "weak_type", False):
            out.append(_f(
                ep.name, f"const {i}",
                f"captured constant {i} has a weak_type aval — pin its "
                f"dtype", ""))
    return out


def check_env_resolution(resolver=None) -> list[Finding]:
    """R002 core: ``REPRO_EVENT_CLOCKS`` must be resolved *eagerly* so it
    participates in jit cache keys. Flips the env var through both values
    and asserts the resolver actually follows it (a lazy resolver — one
    that reads the env only at trace time — returns a stale value here
    and would silently reuse a cached executable of the other
    representation). Pure python, no tracing.
    """
    if resolver is None:
        from repro.kernels.event_loop.ops import resolve_representation
        resolver = resolve_representation
    findings = []
    old = os.environ.get("REPRO_EVENT_CLOCKS")
    try:
        for interpret in (False, True):
            for env in ("i64", "i32pair"):
                os.environ["REPRO_EVENT_CLOCKS"] = env
                got = resolver("auto", interpret)
                if got != env:
                    findings.append(_f(
                        "<global>",
                        f"resolver(auto, interpret={interpret})",
                        f"REPRO_EVENT_CLOCKS={env!r} resolved to {got!r} "
                        f"— the env override is not applied eagerly, so "
                        f"it cannot key the jit cache",
                        "resolve env/static args before the jit boundary "
                        "(ops.run_events_jit pattern)"))
    finally:
        if old is None:
            os.environ.pop("REPRO_EVENT_CLOCKS", None)
        else:
            os.environ["REPRO_EVENT_CLOCKS"] = old
    return findings


def check_runner_cache_keys() -> list[Finding]:
    """R002, second leg: the *sharded* bucket-runner cache
    (``repro.core.batch._bucket_runner``) must key on the resolved
    representation — two different ``REPRO_EVENT_CLOCKS`` settings must
    yield two different cache keys. Builds the runners (python-level;
    nothing is traced or dispatched) and compares the keys."""
    import jax
    from jax.sharding import Mesh
    from repro.core import batch
    findings = []
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    key = ("alock", 4, 2, 8, 64, 0)
    old = os.environ.get("REPRO_EVENT_CLOCKS")
    try:
        cks = {}
        for env in ("i64", "i32pair"):
            os.environ["REPRO_EVENT_CLOCKS"] = env
            _, cks[env] = batch._bucket_runner(key, 1, "pallas", mesh)
        if cks["i64"] == cks["i32pair"]:
            findings.append(_f(
                "<global>", "batch._bucket_runner",
                "the sharded bucket-runner cache key is identical under "
                "REPRO_EVENT_CLOCKS=i64 and =i32pair — a mid-process env "
                "flip would silently reuse a trace of the other "
                "representation",
                "include resolve_representation(...) in the runner cache "
                "key"))
    finally:
        if old is None:
            os.environ.pop("REPRO_EVENT_CLOCKS", None)
        else:
            os.environ["REPRO_EVENT_CLOCKS"] = old
    return findings


@rule("R002", family="retrace-hazards", scope="global",
      summary="env-keyed static args must resolve eagerly (jit cache)")
def _lazy_env(_eps):
    return check_env_resolution() + check_runner_cache_keys()


def bucket_signature(operands) -> tuple:
    """The abstract signature of one lowered replica: (field, shape,
    dtype) triples — what the jit cache sees after the static shape key.
    Two replicas in one sweep bucket with different signatures force a
    recompile."""
    return tuple((f, tuple(np.shape(a)), str(np.asarray(a).dtype))
                 for f, a in zip(type(operands)._fields, operands))


def check_bucket_signatures(n_events: int = 2048,
                            scenarios: Iterable[str] | None = None,
                            lowered_by_bucket=None) -> list[Finding]:
    """R003 core: one-compile-per-bucket, checked by signature hashing —
    **no execution, no tracing**. Mirrors ``batch.sweep``'s bucketing
    (shape key + pad_phases to the bucket max) for every registered
    simulator scenario and asserts each bucket collapses to exactly one
    abstract signature. ``lowered_by_bucket`` injects a pre-bucketed
    ``{bucket_name: [WorkloadOperands]}`` mapping instead (the fixture
    corpus uses this)."""
    findings = []
    if lowered_by_bucket is None:
        from repro.experiments import scenario_names, scenario_workloads
        from repro.workloads import lower, pad_phases
        names = list(scenarios) if scenarios is not None \
            else scenario_names()
        lowered_by_bucket = {}
        for scen in names:
            wls = scenario_workloads(scen)
            if not wls:
                continue
            per_key: dict = {}
            for w in wls:
                lw = lower(w, n_events)
                per_key.setdefault(lw.shape_key, []).append(lw.operands)
            for key, ops in per_key.items():
                pmax = max(o.n_phases for o in ops)
                lowered_by_bucket[f"{scen}:{key}"] = [
                    pad_phases(o, pmax) for o in ops]
    for bucket, ops in lowered_by_bucket.items():
        sigs = {bucket_signature(o) for o in ops}
        if len(sigs) > 1:
            findings.append(_f(
                "<global>", bucket,
                f"sweep bucket holds {len(sigs)} distinct abstract "
                f"signatures across {len(ops)} replicas — each extra "
                f"signature is one silent recompile per sweep",
                "pad_phases/dtype-pin the lowered operands so every "
                "replica of a shape bucket shares one signature"))
    return findings


@rule("R003", family="retrace-hazards", scope="global",
      summary="one compile per sweep bucket (abstract-signature hash)")
def _bucket_sigs(_eps):
    return check_bucket_signatures()


# ---------------------------------------------------------------------------
# vmem-consistency


def check_vmem_consistency(ep, table_fn=None) -> list[Finding]:
    """V001 core: the pure-python VMEM byte table (``vmem.buffer_table``)
    must mirror the buffers the traced ``pallas_call`` actually binds —
    name for name, shape for shape, itemsize for itemsize, in order
    (inputs, outputs, scratch). Drift means the planner budgets a kernel
    that no longer exists. ``table_fn`` injects an alternative table (the
    fixture corpus passes a corrupted one)."""
    from repro.kernels.event_loop import vmem
    if table_fn is None:
        table_fn = vmem.buffer_table
    plan = ep.meta.get("plan")
    if plan is None:
        return []
    calls = [s for s in walk_jaxpr(ep.jaxpr)
             if s.eqn.primitive.name == "pallas_call" and not s.in_kernel]
    if not calls:
        return []
    findings = []
    dims = ep.meta["dims"]            # {T, N, K, P, R}
    table = table_fn(tile=plan.tile, ev_chunk=plan.ev_chunk,
                     lat_samples=plan.lat_samples, repr32=ep.repr32,
                     **dims)
    expected = []
    for name, (shape, nbytes) in table.items():
        factor = vmem.PIPELINE_FACTOR if name in vmem.STREAMED_INPUTS else 1
        itemsize = nbytes // (int(np.prod(shape)) * factor)
        expected.append((name, tuple(shape), itemsize))
    for site in calls:
        kernel = site.eqn.params["jaxpr"]
        refs = [(tuple(v.aval.shape), np.dtype(v.aval.dtype).itemsize)
                for v in kernel.invars]
        if len(refs) != len(expected):
            findings.append(_f(
                ep.name, f"pallas_call @ {site.src}",
                f"planner prices {len(expected)} VMEM buffers but the "
                f"kernel binds {len(refs)} — a buffer was added/removed "
                f"without updating vmem.buffer_table",
                "keep vmem.buffer_table in lockstep with ops.run_events' "
                "in_specs/out_specs/scratch_shapes"))
            continue
        for (name, eshape, esize), (kshape, ksize) in zip(expected, refs):
            if eshape != kshape or esize != ksize:
                findings.append(_f(
                    ep.name, f"pallas_call @ {site.src}",
                    f"VMEM plan drift at `{name}`: planner says shape "
                    f"{eshape} x {esize}B/elt, kernel binds {kshape} x "
                    f"{ksize}B/elt",
                    "update vmem.buffer_table (and its docstring table) "
                    "to match the kernel"))
    return findings


@rule("V001", family="vmem-consistency",
      summary="vmem.py byte table must match the traced kernel buffers")
def _vmem_drift(ep):
    return check_vmem_consistency(ep)

"""Static import-graph report: which ``src/repro`` modules are dead weight.

Parses every module under ``src/repro`` with ``ast`` (nothing is
imported or executed), resolves ``import``/``from``-imports — including
relative and function-local ones — to edges between repo modules, and
walks reachability from the engine's entry packages
(:data:`ROOT_PACKAGES`). Modules no root can reach are *unreachable*:
nothing the engine, the experiment registry or the coordinator runs can
ever import them.

The report is *actionable*, not informational: every unreachable module
must either be wired into an entry package or carry an explicit
:data:`QUARANTINED` entry naming why it is parked. An unreachable module
with no quarantine entry — or a quarantine entry that went stale (its
modules vanished or became reachable) — exits nonzero, which is what the
CI ``lint`` leg gates on (``python -m repro.analysis --imports``).

Resolution rules:

  * ``from repro.a.b import c`` edges to ``repro.a.b.c`` when that is a
    module, else to ``repro.a.b``;
  * importing ``repro.a.b`` also edges to package ``repro.a`` (its
    ``__init__`` runs) — namespace dirs without an ``__init__.py`` (e.g.
    ``repro`` itself, ``coord``, ``serve``) contribute no such edge;
  * relative imports resolve against the importing module's package;
  * imports of modules outside ``src/repro`` are ignored.

>>> g = build_graph()
>>> "repro.core.sim" in g.modules
True
>>> "repro.kernels.event_loop.i32pair" in g.reachable()
True
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path

__all__ = ["ROOT_PACKAGES", "QUARANTINED", "ImportGraph", "build_graph",
           "report", "classify"]

#: reachability roots: the packages whose public surface the engine, the
#: scenario registry and the coordinator expose. For a namespace package
#: (no ``__init__.py``) the roots are its direct child modules.
#: ``repro.analysis.__main__`` is the lint CLI itself — an executable
#: entry, reached by ``python -m``, not by imports.
ROOT_PACKAGES = ("repro.core", "repro.kernels", "repro.workloads",
                 "repro.experiments", "repro.coord",
                 "repro.analysis", "repro.analysis.__main__")

#: Explicitly parked module trees: unreachable from every root *on
#: purpose*, with the reason recorded here. A prefix covers the module
#: itself and everything below it. Anything unreachable and NOT covered
#: fails the ``--imports`` gate; so does a stale entry (no unreachable
#: module under the prefix anymore — delete the entry when the tree is
#: wired in or removed).
QUARANTINED: dict[str, str] = {
    # the dead seed stack (repro.models / repro.configs / repro.serve,
    # plus the empty repro.train / repro.launch dirs) was deleted
    # outright — repro.parallel.sharding survives, slimmed to the
    # shard_map wrapper batch.sweep's chunked dispatch imports
    "repro.core.tla": "TLA+ spec emitter — developer tooling invoked by "
                      "hand, deliberately outside the engine's import "
                      "surface",
    "repro.kernels.alock_tick": "superseded by kernels.event_loop (the "
                                "event-driven engine); retained for the "
                                "kernel-evolution narrative in docs",
    "repro.kernels.flash_attention": "exemplar Pallas kernel from the "
                                     "seed, unrelated to the lock "
                                     "simulator; reference material only",
    "repro.kernels.ssd_scan": "exemplar Pallas kernel from the seed, "
                              "unrelated to the lock simulator; "
                              "reference material only",
}


def _src_root() -> Path:
    return Path(__file__).resolve().parent.parent


@dataclass
class ImportGraph:
    modules: dict = field(default_factory=dict)   # name -> Path
    edges: dict = field(default_factory=dict)     # name -> set[str]

    def roots(self) -> list:
        out = []
        for pkg in ROOT_PACKAGES:
            if pkg in self.modules:               # real package: __init__
                out.append(pkg)
            else:                                 # namespace: direct children
                prefix = pkg + "."
                out += [m for m in self.modules
                        if m.startswith(prefix)
                        and "." not in m[len(prefix):]]
        return sorted(set(out))

    def reachable(self) -> set:
        seen, todo = set(), list(self.roots())
        while todo:
            m = todo.pop()
            if m in seen:
                continue
            seen.add(m)
            todo += [d for d in self.edges.get(m, ()) if d not in seen]
        return seen

    def unreachable(self) -> list:
        return sorted(set(self.modules) - self.reachable())


def _module_name(path: Path, src: Path) -> str:
    rel = path.relative_to(src).with_suffix("")
    parts = ("repro",) + rel.parts
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _resolve(target: str, modules: dict) -> list:
    """Longest known prefix of a dotted import target (with its package
    chain), or [] for anything outside the repo."""
    out = []
    parts = target.split(".")
    for i in range(len(parts), 0, -1):
        cand = ".".join(parts[:i])
        if cand in modules:
            out.append(cand)
            # packages up the chain run their __init__ on import
            for j in range(i - 1, 0, -1):
                pkg = ".".join(parts[:j])
                if pkg in modules:
                    out.append(pkg)
            break
    return out


def build_graph(src: Path | None = None) -> ImportGraph:
    src = Path(src) if src is not None else _src_root()
    g = ImportGraph()
    for path in sorted(src.rglob("*.py")):
        g.modules[_module_name(path, src)] = path
    for name, path in g.modules.items():
        deps = g.edges.setdefault(name, set())
        try:
            tree = ast.parse(path.read_text(encoding="utf-8"))
        except SyntaxError:
            continue
        pkg_parts = name.split(".")[:-1] if not _is_pkg(name, g.modules) \
            else name.split(".")
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    deps.update(_resolve(alias.name, g.modules))
            elif isinstance(node, ast.ImportFrom):
                if node.level:                    # relative import
                    base = pkg_parts[:len(pkg_parts) - node.level + 1]
                    mod = ".".join(base + ([node.module]
                                           if node.module else []))
                else:
                    mod = node.module or ""
                for alias in node.names:
                    hits = _resolve(f"{mod}.{alias.name}", g.modules) \
                        or _resolve(mod, g.modules)
                    deps.update(hits)
        deps.discard(name)
    return g


def _is_pkg(name: str, modules: dict) -> bool:
    path = modules.get(name)
    return path is not None and path.name == "__init__.py"


def _covering(module: str) -> str | None:
    """The QUARANTINED prefix covering ``module``, if any."""
    for prefix in QUARANTINED:
        if module == prefix or module.startswith(prefix + "."):
            return prefix
    return None


def classify(src: Path | None = None) -> tuple:
    """Split the graph's unreachable set against :data:`QUARANTINED`.

    Returns ``(quarantined, unexpected, stale)``: unreachable modules
    covered by a quarantine prefix, unreachable modules covered by
    nothing (gate failures), and quarantine prefixes that no longer
    cover any unreachable module (stale entries — also gate failures).
    """
    g = build_graph(src)
    dead = g.unreachable()
    quarantined = [m for m in dead if _covering(m)]
    unexpected = [m for m in dead if not _covering(m)]
    hit = {_covering(m) for m in quarantined}
    stale = sorted(p for p in QUARANTINED if p not in hit)
    return quarantined, unexpected, stale


def report(src: Path | None = None) -> tuple:
    """The ``--imports`` gate: ``(human-readable text, exit code)``.

    Exit 0 iff every unreachable module is explicitly quarantined and
    every quarantine entry still earns its keep.
    """
    g = build_graph(src)
    quarantined, unexpected, stale = classify(src)
    dead = g.unreachable()
    rel = _src_root()
    lines = [f"import graph: {len(g.modules)} modules under src/repro, "
             f"{len(g.roots())} roots, "
             f"{len(g.reachable())} reachable, {len(dead)} unreachable "
             f"({len(quarantined)} quarantined, {len(unexpected)} "
             f"unexpected)",
             f"roots: {', '.join(ROOT_PACKAGES)}", ""]
    if quarantined:
        lines.append("quarantined (unreachable on purpose — see "
                     "repro.analysis.imports.QUARANTINED):")
        last = None
        for m in quarantined:
            prefix = _covering(m)
            if prefix != last:
                lines.append(f"  [{prefix}] {QUARANTINED[prefix]}")
                last = prefix
            lines.append(f"    {m}  ({g.modules[m].relative_to(rel)})")
        lines.append("")
    if unexpected:
        lines.append("UNEXPECTED unreachable modules — wire them into an "
                     "entry package, delete them, or quarantine them "
                     "with a reason:")
        for m in unexpected:
            lines.append(f"  {m}  ({g.modules[m].relative_to(rel)})")
        lines.append("")
    if stale:
        lines.append("STALE quarantine entries — every module under the "
                     "prefix is now reachable (or gone); delete the "
                     "entry:")
        for p in stale:
            lines.append(f"  {p}")
        lines.append("")
    ok = not unexpected and not stale
    lines.append("imports gate: "
                 + ("clean." if ok else "FAILED (see above)."))
    return "\n".join(lines), (0 if ok else 1)

"""Static import-graph report: which ``src/repro`` modules are dead weight.

Parses every module under ``src/repro`` with ``ast`` (nothing is
imported or executed), resolves ``import``/``from``-imports — including
relative and function-local ones — to edges between repo modules, and
walks reachability from the engine's entry packages
(:data:`ROOT_PACKAGES`). Modules no root can reach are *unreachable*:
nothing the engine, the experiment registry, the coordinator or the
serving layer runs can ever import them.

Report-only by design: unreachable modules are candidates for deletion or
for wiring into an entrypoint, not CI failures — the CI ``lint`` leg
uploads the report as an artifact (``python -m repro.analysis --imports``)
so the drift is visible per-PR without blocking anyone.

Resolution rules:

  * ``from repro.a.b import c`` edges to ``repro.a.b.c`` when that is a
    module, else to ``repro.a.b``;
  * importing ``repro.a.b`` also edges to package ``repro.a`` (its
    ``__init__`` runs) — namespace dirs without an ``__init__.py`` (e.g.
    ``repro`` itself, ``coord``, ``serve``) contribute no such edge;
  * relative imports resolve against the importing module's package;
  * imports of modules outside ``src/repro`` are ignored.

>>> g = build_graph()
>>> "repro.core.sim" in g.modules
True
>>> "repro.kernels.event_loop.i32pair" in g.reachable()
True
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path

__all__ = ["ROOT_PACKAGES", "ImportGraph", "build_graph", "report"]

#: reachability roots: the packages whose public surface the engine, the
#: scenario registry, the coordinator and the serving layer expose. For a
#: namespace package (no ``__init__.py``) the roots are its direct child
#: modules.
ROOT_PACKAGES = ("repro.core", "repro.kernels", "repro.workloads",
                 "repro.experiments", "repro.coord", "repro.serve",
                 "repro.analysis")


def _src_root() -> Path:
    return Path(__file__).resolve().parent.parent


@dataclass
class ImportGraph:
    modules: dict = field(default_factory=dict)   # name -> Path
    edges: dict = field(default_factory=dict)     # name -> set[str]

    def roots(self) -> list:
        out = []
        for pkg in ROOT_PACKAGES:
            if pkg in self.modules:               # real package: __init__
                out.append(pkg)
            else:                                 # namespace: direct children
                prefix = pkg + "."
                out += [m for m in self.modules
                        if m.startswith(prefix)
                        and "." not in m[len(prefix):]]
        return sorted(set(out))

    def reachable(self) -> set:
        seen, todo = set(), list(self.roots())
        while todo:
            m = todo.pop()
            if m in seen:
                continue
            seen.add(m)
            todo += [d for d in self.edges.get(m, ()) if d not in seen]
        return seen

    def unreachable(self) -> list:
        return sorted(set(self.modules) - self.reachable())


def _module_name(path: Path, src: Path) -> str:
    rel = path.relative_to(src).with_suffix("")
    parts = ("repro",) + rel.parts
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _resolve(target: str, modules: dict) -> list:
    """Longest known prefix of a dotted import target (with its package
    chain), or [] for anything outside the repo."""
    out = []
    parts = target.split(".")
    for i in range(len(parts), 0, -1):
        cand = ".".join(parts[:i])
        if cand in modules:
            out.append(cand)
            # packages up the chain run their __init__ on import
            for j in range(i - 1, 0, -1):
                pkg = ".".join(parts[:j])
                if pkg in modules:
                    out.append(pkg)
            break
    return out


def build_graph(src: Path | None = None) -> ImportGraph:
    src = Path(src) if src is not None else _src_root()
    g = ImportGraph()
    for path in sorted(src.rglob("*.py")):
        g.modules[_module_name(path, src)] = path
    for name, path in g.modules.items():
        deps = g.edges.setdefault(name, set())
        try:
            tree = ast.parse(path.read_text(encoding="utf-8"))
        except SyntaxError:
            continue
        pkg_parts = name.split(".")[:-1] if not _is_pkg(name, g.modules) \
            else name.split(".")
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    deps.update(_resolve(alias.name, g.modules))
            elif isinstance(node, ast.ImportFrom):
                if node.level:                    # relative import
                    base = pkg_parts[:len(pkg_parts) - node.level + 1]
                    mod = ".".join(base + ([node.module]
                                           if node.module else []))
                else:
                    mod = node.module or ""
                for alias in node.names:
                    hits = _resolve(f"{mod}.{alias.name}", g.modules) \
                        or _resolve(mod, g.modules)
                    deps.update(hits)
        deps.discard(name)
    return g


def _is_pkg(name: str, modules: dict) -> bool:
    path = modules.get(name)
    return path is not None and path.name == "__init__.py"


def report(src: Path | None = None) -> str:
    """Human-readable unreachability report (the ``--imports`` output)."""
    g = build_graph(src)
    dead = g.unreachable()
    lines = [f"import graph: {len(g.modules)} modules under src/repro, "
             f"{len(g.roots())} roots, "
             f"{len(g.reachable())} reachable, {len(dead)} unreachable",
             f"roots: {', '.join(ROOT_PACKAGES)}", ""]
    if not dead:
        lines.append("no unreachable modules.")
    else:
        lines.append("unreachable from every entry package "
                     "(deletion / wiring candidates):")
        for m in dead:
            lines.append(f"  {m}  ({g.modules[m].relative_to(_src_root())})")
    return "\n".join(lines)

"""Generic jaxpr equation walker with sub-jaxpr recursion + provenance.

Every rule in ``repro.analysis.rules`` consumes the same traversal: a
depth-first walk over a (closed) jaxpr's equations that recurses into
*every* sub-jaxpr an equation carries in its params — ``pjit``'s inner
jaxpr, ``scan``/``while`` body/cond jaxprs, ``cond``'s branch list,
``shard_map``'s body and — the one the Mosaic rules care about —
``pallas_call``'s kernel jaxpr. Recursion is structural (any param value
that *is* or *wraps* a jaxpr), so new higher-order primitives are walked
without code changes here.

Each visited equation is yielded as a :class:`EqnSite` carrying

  * ``path`` — the chain of enclosing higher-order primitives, e.g.
    ``"pjit/pallas_call/scan"`` (the outermost call is ``""``);
  * ``in_kernel`` — True once the walk has crossed a ``pallas_call``
    boundary, i.e. the equation executes *inside* the Mosaic kernel
    (where TPU vector-unit restrictions apply);
  * ``src`` — best-effort ``file:line`` provenance of the traced line.

>>> import jax, jax.numpy as jnp
>>> jx = jax.make_jaxpr(lambda x: jax.lax.scan(
...     lambda c, t: (c + t, c), x, jnp.ones(3)))(1.0)
>>> names = [s.eqn.primitive.name for s in walk_jaxpr(jx.jaxpr)]
>>> "scan" in names, "add" in names
(True, True)
>>> {s.path for s in walk_jaxpr(jx.jaxpr) if s.eqn.primitive.name == "add"}
{'scan'}
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Iterator

__all__ = ["EqnSite", "walk_jaxpr", "all_avals", "eqn_src"]


@dataclass(frozen=True)
class EqnSite:
    """One visited equation, with where-it-lives context."""
    eqn: Any            # jax.core.JaxprEqn
    path: str           # "/"-joined enclosing higher-order primitives
    depth: int
    in_kernel: bool     # inside a pallas_call kernel jaxpr

    @property
    def src(self) -> str:
        return eqn_src(self.eqn)


def eqn_src(eqn) -> str:
    """Best-effort ``file:line`` of the python line that traced ``eqn``."""
    try:
        from jax._src import source_info_util
        return source_info_util.summarize(eqn.source_info)
    except Exception:
        return "<unknown>"


def _sub_jaxprs(eqn) -> Iterator[Any]:
    """Every jaxpr an equation's params reference (ClosedJaxpr unwrapped;
    lists/tuples — e.g. ``cond``'s branches — flattened)."""
    for val in eqn.params.values():
        items = val if isinstance(val, (list, tuple)) else (val,)
        for item in items:
            inner = getattr(item, "jaxpr", item)   # ClosedJaxpr -> Jaxpr
            if hasattr(inner, "eqns"):
                yield inner


def walk_jaxpr(jaxpr, path: str = "", depth: int = 0,
               in_kernel: bool = False) -> Iterator[EqnSite]:
    """Depth-first over ``jaxpr.eqns``, recursing into sub-jaxprs.

    ``jaxpr`` may be open or closed. Parents are yielded before their
    sub-jaxpr bodies; ``in_kernel`` turns (and stays) True below a
    ``pallas_call`` equation.
    """
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)         # ClosedJaxpr -> Jaxpr
    for eqn in jaxpr.eqns:
        yield EqnSite(eqn, path, depth, in_kernel)
        name = eqn.primitive.name
        sub_path = f"{path}/{name}" if path else name
        sub_kernel = in_kernel or name == "pallas_call"
        for sub in _sub_jaxprs(eqn):
            yield from walk_jaxpr(sub, sub_path, depth + 1, sub_kernel)


def all_avals(jaxpr, include_invars: bool = True) -> Iterator[tuple]:
    """Every abstract value in the (recursively walked) jaxpr, as
    ``(aval, where)`` pairs — invars/constvars of the top jaxpr plus each
    equation's operands and outputs. ``where`` is a human-readable site."""
    top = getattr(jaxpr, "jaxpr", jaxpr)
    if include_invars:
        for v in list(top.invars) + list(top.constvars):
            yield v.aval, "<entry operand>"
    for site in walk_jaxpr(jaxpr):
        for v in list(site.eqn.invars) + list(site.eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is not None:
                where = (f"{site.path}/{site.eqn.primitive.name}"
                         if site.path else site.eqn.primitive.name)
                yield aval, f"{where} @ {site.src}"

"""Fail-slow fault-injection satellites: node_mult spec forms, lowering,
and the monotone-degradation property (raising a node's multiplier never
decreases that node's observed p50 latency)."""
import numpy as np
import pytest
import jax.numpy as jnp
from jax.experimental import enable_x64

from hypothesis_compat import given, settings, st
from repro.core.sim import topology
from repro.kernels.event_loop.ref import run_events_ref
from repro.workloads import (NODE_MULT_PROFILES, Phase, Workload,
                             WorkloadOperands, freeze_node_mult, lower,
                             node_mult_pairs, resolve_node_mult)

# ---------------------------------------------------------------- spec


def test_freeze_node_mult_forms():
    assert freeze_node_mult(None) is None
    assert freeze_node_mult("healthy") == "healthy"
    assert freeze_node_mult({2: 4.0, 0: 2.0}) == ((0, 2.0), (2, 4.0))
    assert node_mult_pairs("limp-node0-4x") == ((0, 4.0),)
    assert resolve_node_mult({1: 3.0}, 4) == (1.0, 3.0, 1.0, 1.0)
    assert resolve_node_mult(None, 3) == (1.0, 1.0, 1.0)
    assert "limp-node0-2x" in NODE_MULT_PROFILES


def test_node_mult_validation():
    with pytest.raises(ValueError, match="profile"):
        freeze_node_mult("no-such-profile")
    with pytest.raises(ValueError, match="> 0"):
        freeze_node_mult({0: 0.0})
    with pytest.raises(ValueError, match="> 0"):
        freeze_node_mult({0: float("inf")})
    with pytest.raises(ValueError, match="duplicate"):
        freeze_node_mult([(0, 2.0), (0, 3.0)])
    with pytest.raises(ValueError, match="node ids"):
        Workload("alock", 2, 2, 4, node_mult={5: 2.0})
    with pytest.raises(ValueError, match=r"phases\[1\].node_mult"):
        Workload("alock", 2, 2, 4,
                 phases=(Phase(frac=0.5),
                         Phase(frac=0.5, node_mult={3: 2.0})))
    # frozen specs stay hashable and comparable
    a = Workload("alock", 2, 2, 4, node_mult={0: 2.0})
    b = Workload("alock", 2, 2, 4, node_mult=[(0, 2.0)])
    assert a == b and hash(a) == hash(b)


def test_lowering_emits_per_phase_node_mult_rows():
    w = Workload("alock", 3, 2, 6, node_mult={2: 2.0},
                 phases=(Phase(frac=0.5),
                         Phase(frac=0.5, node_mult="limp-node0-4x")))
    o = lower(w, 1000).operands
    assert o.node_mult.shape == (2, 3)
    assert o.node_mult.dtype == np.float32
    np.testing.assert_array_equal(o.node_mult,
                                  [[1.0, 1.0, 2.0],   # workload base
                                   [4.0, 1.0, 1.0]])  # phase override


# ------------------------------------------------- monotone degradation


def _node_p50(node, mult, seed, ev=800, lat_samples=512):
    """p50 acquire->release latency observed *on* ``node``: every other
    node is parked for the whole run, so the latency pool is exactly the
    degraded node's own traffic."""
    N, tpn, K = 2, 2, 4
    others = tuple(n for n in range(N) if n != node)
    w = Workload("alock", N, tpn, K, locality=1.0, seed=seed,
                 node_mult={node: float(mult)},
                 phases=(Phase(frac=1.0, down_nodes=others),))
    lw = lower(w, ev)
    alg, T, N_, K_, _, _ = lw.shape_key
    tn, ln, _ = topology(alg, N_, tpn, K_)
    wl = WorkloadOperands(*(jnp.asarray(a)[None] for a in lw.operands))
    with enable_x64():
        done, lat, lat_n, *_ = run_events_ref(alg, T, N_, K_, ev, wl, tn,
                                              ln, lat_samples=lat_samples)
    n = int(min(int(lat_n[0]), lat_samples))
    assert n > 0
    return float(np.percentile(np.asarray(lat[0][:n]), 50))


def test_monotone_degradation_chain():
    """Deterministic spine of the property (runs without hypothesis):
    1x -> 2x -> 4x never decreases the node's p50, on either node."""
    for node in (0, 1):
        p50s = [_node_p50(node, m, seed=3) for m in (1.0, 2.0, 4.0)]
        assert p50s == sorted(p50s), (node, p50s)
        assert p50s[-1] > p50s[0]       # 4x really hurts


@settings(max_examples=8, deadline=None, derandomize=True)
@given(node=st.integers(0, 1),
       lo=st.floats(1.0, 4.0), factor=st.floats(1.0, 4.0),
       seed=st.integers(0, 2**16))
def test_monotone_degradation_property(node, lo, factor, seed):
    """Raising any node's fail-slow multiplier never decreases that
    node's observed p50 latency."""
    hi = lo * factor
    assert _node_p50(node, hi, seed) >= _node_p50(node, lo, seed)

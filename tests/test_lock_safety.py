"""Trace-level lock-safety campaign over per-event CS intervals.

A schedule replay through the canonical state machines
(``repro.core.machine.MACHINES``) yields, per thread, the closed critical-
section intervals ``(tid, kind, t0, t1)`` in step time, where ``kind`` is
``"write"`` (the exclusive CS) or ``"read"`` (alock-rw's shared reader
section, held from the successful rd_enter until the RD_REL decrement).
``check_cs_intervals`` is the safety oracle over that trace:

  * **write mutual exclusion** — no two write intervals of different
    threads ever overlap (all five algorithms);
  * **reader/writer exclusion** — a read interval may overlap other read
    intervals but never a write interval (alock-rw).

The checker is exercised three ways: seeded adversarial schedules that
always run (no external deps), hypothesis properties when hypothesis is
installed (``hypothesis_compat`` degrades them to skips otherwise), and a
*seeded mutation* — an alock-rw writer whose reader-count drain check is
disabled — which the checker must catch (a checker that cannot fail would
gate nothing).
"""
import itertools
import random

import pytest
from hypothesis_compat import given, settings, st

from repro.core import machine as mc

L, R = mc.LOCAL, mc.REMOTE

ALGS = ("alock", "spinlock", "mcs", "hlock", "alock-rw")


# ---------------------------------------------------------------------------
# trace extraction + the safety oracle


def cs_intervals(alg, cohorts, b_init, sched, read_bits=(),
                 step_fn=None):
    """Replay ``sched`` through ``MACHINES[alg]`` and return the CS trace.

    ``sched`` is a sequence of thread ids (one atomic action each);
    ``read_bits`` (alock-rw only) supplies the per-step read/write coin a
    thread consults when it leaves NCS — mirroring the engine's per-
    request draw. Returns ``[(tid, kind, t0, t1), ...]`` with half-open
    step intervals ``[t0, t1)``; ``step_fn`` overrides the machine (the
    mutation tests inject a broken writer through it).
    """
    n = len(cohorts)
    step = step_fn if step_fn is not None else mc.MACHINES[alg]
    stt = mc.initial_state(n)
    open_iv: dict = {}
    out = []
    for t, tid in enumerate(sched):
        if alg == "alock-rw":
            is_read = bool(read_bits[t]) if len(read_bits) else False
            stt, _ = step(stt, tid, cohorts[tid], b_init, is_read=is_read)
        else:
            stt, _ = step(stt, tid, cohorts[tid], b_init)
        for u in range(n):
            kind = ("write" if mc.in_cs(stt, u)
                    else "read" if alg == "alock-rw" and mc.in_read_cs(
                        stt, u)
                    else None)
            cur = open_iv.get(u)
            if cur is not None and cur[0] != kind:
                out.append((u, cur[0], cur[1], t + 1))
                del open_iv[u]
                cur = None
            if kind is not None and cur is None:
                open_iv[u] = (kind, t + 1)
    for u, (kind, t0) in sorted(open_iv.items()):
        out.append((u, kind, t0, len(sched) + 1))
    return out


def check_cs_intervals(intervals):
    """The oracle: every overlapping pair of intervals from *different*
    threads involving a write is a violation. Returns the violating
    pairs (empty = trace is safe)."""
    viol = []
    for a, b in itertools.combinations(intervals, 2):
        (u, ku, a0, a1), (v, kv, b0, b1) = a, b
        if u == v:
            continue
        if a0 < b1 and b0 < a1 and ("write" in (ku, kv)):
            viol.append((a, b))
    return viol


def _coins(seed, n_steps, p_read):
    rng = random.Random(seed)
    return [1 if rng.random() < p_read else 0 for _ in range(n_steps)]


def _sched(seed, n_threads, n_steps):
    rng = random.Random(seed)
    return [rng.randrange(n_threads) for _ in range(n_steps)]


# ---------------------------------------------------------------------------
# the checker on its own terms (unit): overlap logic, read/read tolerance


def test_checker_flags_write_write_overlap():
    bad = [(0, "write", 3, 9), (1, "write", 8, 12)]
    assert check_cs_intervals(bad)
    ok = [(0, "write", 3, 8), (1, "write", 8, 12)]   # half-open: no touch
    assert not check_cs_intervals(ok)


def test_checker_read_rules():
    rr = [(0, "read", 1, 10), (1, "read", 2, 8), (2, "read", 5, 20)]
    assert not check_cs_intervals(rr)               # readers share freely
    rw = rr + [(3, "write", 7, 9)]
    viol = check_cs_intervals(rw)
    assert len(viol) == 3                           # ... but never a writer
    # same thread re-entering is not an overlap
    assert not check_cs_intervals([(0, "write", 1, 5), (0, "write", 4, 9)])


# ---------------------------------------------------------------------------
# seeded adversarial schedules: always run (no hypothesis needed)


@pytest.mark.parametrize("alg", ALGS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_safety_seeded_schedules(alg, seed):
    """All five algorithms stay safe under seeded adversarial schedules,
    mixed cohorts and tight budgets (the regime that maximizes Peterson
    re-acquires and lease handoffs)."""
    cohorts = ((L, L, R, R), (L, R, R, R), (L, L, L, R))[seed]
    b_init = ((1, 1), (2, 3), (1, 4))[seed]
    n_steps = 4000
    sched = _sched(seed * 17 + 5, len(cohorts), n_steps)
    coins = _coins(seed * 31 + 7, n_steps, p_read=(0.3, 0.7, 0.95)[seed])
    iv = cs_intervals(alg, cohorts, b_init, sched, read_bits=coins)
    assert iv, "schedule never reached a critical section"
    assert check_cs_intervals(iv) == []
    if alg == "alock-rw":
        kinds = {k for _, k, _, _ in iv}
        assert kinds == {"read", "write"}, kinds


def test_alock_rw_readers_really_share():
    """The shared section is observable: some read intervals of different
    threads overlap (otherwise the reader path would be indistinguishable
    from a mutex and the exclusion checks above would be vacuous)."""
    cohorts = (L, L, R, R)
    n_steps = 4000
    sched = _sched(11, len(cohorts), n_steps)
    coins = _coins(13, n_steps, p_read=0.9)
    iv = cs_intervals("alock-rw", cohorts, (2, 3), sched, read_bits=coins)
    reads = [i for i in iv if i[1] == "read"]
    shared = [(a, b) for a, b in itertools.combinations(reads, 2)
              if a[0] != b[0] and a[2] < b[3] and b[2] < a[3]]
    assert shared, "no two readers ever overlapped"


# ---------------------------------------------------------------------------
# the seeded mutation: a checker that cannot fail gates nothing


def _mutant_rw_step(stt, tid, cohort, b_init, is_read=False):
    """alock-rw with the writer's reader-count drain check disabled: at
    WR_DRAIN the writer enters the CS without looking at ``word``."""
    if stt.pc[tid] == mc.WR_DRAIN:
        stt = stt._replace(pc=stt.pc[:tid] + (mc.CS,) + stt.pc[tid + 1:])
        return stt, mc.Op("wr_drained", "local", True)
    return mc.alock_rw_step(stt, tid, cohort, b_init, is_read=is_read)


def test_mutation_disabled_drain_is_caught():
    """Disabling the reader-count drain must produce a reader/writer
    overlap the checker reports — on a targeted schedule and under seeded
    random ones."""
    cohorts = (L, R)
    # targeted: T0 enters the read CS, then T1 walks the writer path and
    # (mutant) barges past the drain while the reader still holds
    sched = [0, 0, 1, 1, 1, 1, 1, 1, 1]
    coins = [1, 1, 0, 0, 0, 0, 0, 0, 0]
    iv = cs_intervals("alock-rw", cohorts, (2, 3), sched, read_bits=coins,
                      step_fn=_mutant_rw_step)
    viol = check_cs_intervals(iv)
    assert viol, iv
    kinds = {frozenset((a[1], b[1])) for a, b in viol}
    assert frozenset(("read", "write")) in kinds
    # and the same mutant caught from a plain seeded schedule
    n_steps = 4000
    iv = cs_intervals("alock-rw", (L, L, R, R), (2, 3),
                      _sched(3, 4, n_steps),
                      read_bits=_coins(4, n_steps, 0.6),
                      step_fn=_mutant_rw_step)
    assert check_cs_intervals(iv)
    # the unmutated machine on the identical schedules stays clean
    clean = cs_intervals("alock-rw", cohorts, (2, 3), sched,
                         read_bits=coins)
    assert check_cs_intervals(clean) == []


# ---------------------------------------------------------------------------
# hypothesis properties (skip cleanly when hypothesis is absent)


@given(st.lists(st.integers(0, 3), min_size=200, max_size=1500),
       st.sampled_from(ALGS),
       st.sampled_from([(L, L, R, R), (L, R, R, R), (L, L, L, R)]),
       st.tuples(st.integers(1, 4), st.integers(1, 6)))
def test_safety_property_all_algorithms(sched, alg, cohorts, b_init):
    """Hypothesis schedules: the CS-interval trace of every algorithm
    passes the oracle (write mutex; reader/writer exclusion)."""
    coins = _coins(sum(sched) + len(sched), len(sched), p_read=0.5)
    iv = cs_intervals(alg, cohorts, b_init, sched, read_bits=coins)
    assert check_cs_intervals(iv) == []


@given(st.integers(0, 2**31 - 1), st.floats(0.05, 0.99))
@settings(max_examples=25)
def test_safety_property_rw_mixes(seed, p_read):
    """alock-rw across the whole read-mix axis: safe at every mix, and
    the trace contains both kinds once both coins have landed."""
    n_steps = 2500
    sched = _sched(seed, 4, n_steps)
    coins = _coins(seed ^ 0x9E3779B9, n_steps, p_read)
    iv = cs_intervals("alock-rw", (L, L, R, R), (2, 3), sched,
                      read_bits=coins)
    assert check_cs_intervals(iv) == []

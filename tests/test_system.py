"""End-to-end behaviour: train -> crash -> restart -> converge -> serve."""

import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.serve.engine import Engine, ServeConfig
from repro.train.loop import LoopConfig, Trainer
from repro.train.optimizer import OptConfig


@pytest.fixture()
def ckpt_dir(tmp_path):
    return str(tmp_path / "ckpt")


def test_train_crash_restart_and_serve(ckpt_dir):
    cfg = get_config("yi-9b").tiny()
    loop = LoopConfig(steps=30, ckpt_every=10, ckpt_dir=ckpt_dir,
                      seq_len=32, batch_per_shard=2, n_shards=2,
                      fail_at_step=25, log_every=5)
    opt = OptConfig(lr=2e-3, warmup_steps=5, total_steps=30)
    tr = Trainer(cfg, opt, loop)
    with pytest.raises(RuntimeError, match="injected failure"):
        tr.run()
    # restart resumes from the step-20 checkpoint, not step 0
    loop2 = LoopConfig(**{**loop.__dict__, "fail_at_step": None})
    tr2 = Trainer(cfg, opt, loop2)
    state = tr2.run()
    assert tr2.history[0]["step"] == 20
    assert int(state["step"]) == 30
    # serve from the trained weights
    eng = Engine(cfg, state["params"], ServeConfig(max_new_tokens=4))
    out = eng.generate({"tokens": jnp.ones((3, 12), jnp.int32) * 5})
    assert out.shape == (3, 4)
    assert (out >= 0).all() and (out < cfg.vocab).all()


def test_training_reduces_loss(ckpt_dir):
    """The synthetic affine-mod task is learnable: loss must fall
    substantially from its ln(V) start toward the ln(3) floor."""
    cfg = get_config("gemma3-1b").tiny()
    loop = LoopConfig(steps=80, ckpt_every=1000, ckpt_dir=ckpt_dir,
                      seq_len=64, batch_per_shard=4, n_shards=2,
                      log_every=10)
    opt = OptConfig(lr=5e-3, warmup_steps=10, total_steps=80)
    tr = Trainer(cfg, opt, loop)
    tr.run(resume=False)
    first = tr.history[0]["loss"]
    last = tr.history[-1]["loss"]
    assert last < first - 1.0, (first, last)

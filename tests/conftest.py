import os

# Tests must see exactly ONE CPU device (the 512-device override belongs to
# launch/dryrun.py only). Also keep compilation deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

from hypothesis import HealthCheck, settings

settings.register_profile(
    "repro", deadline=None, max_examples=30,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
settings.load_profile("repro")

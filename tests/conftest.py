import os

# Tests must see exactly ONE CPU device (the 512-device override belongs to
# launch/dryrun.py only). Also keep compilation deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# hypothesis is optional (offline CI images lack it): register the profile
# only when present; property tests gate themselves via hypothesis_compat.
try:
    from hypothesis import HealthCheck, settings
except ModuleNotFoundError:
    pass
else:
    settings.register_profile(
        "repro", deadline=None, max_examples=30,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large])
    settings.load_profile("repro")

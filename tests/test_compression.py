"""int8 gradient compression: roundtrip error bounds, payload size, and
error-feedback unbiasedness over repeated rounds."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.compression import (compressed_bytes, dequantize_int8,
                                        quantize_int8)


def test_quantize_roundtrip_bounded():
    g = jax.random.normal(jax.random.key(0), (1000,)) * 3.0
    q, s = quantize_int8(g)
    deq = dequantize_int8(q, s, g.shape)
    err = np.abs(np.asarray(deq - g))
    # per-block max error <= scale/2
    scales = np.repeat(np.asarray(s), 256)[:1000]
    assert (err <= scales * 0.5 + 1e-7).all()


def test_payload_is_quarter_of_f32():
    g = {"a": jnp.zeros((512, 64)), "b": jnp.zeros((1000,))}
    f32_bytes = sum(x.size * 4 for x in jax.tree_util.tree_leaves(g))
    c = compressed_bytes(g)
    assert c < 0.30 * f32_bytes   # int8 + scales ~= 0.26x


def test_error_feedback_unbiased_over_rounds():
    """With error feedback, the SUM of transmitted (dequantized) values
    converges to the sum of true gradients — the residual stays bounded."""
    key = jax.random.key(1)
    err = jnp.zeros(512)
    sent_total = jnp.zeros(512)
    true_total = jnp.zeros(512)
    for i in range(50):
        g = jax.random.normal(jax.random.fold_in(key, i), (512,))
        x = g + err
        q, s = quantize_int8(x)
        deq = dequantize_int8(q, s, x.shape)
        err = x - deq
        sent_total = sent_total + deq
        true_total = true_total + g
    resid = np.abs(np.asarray(sent_total - true_total))
    # residual equals the final carried error (telescoping) — bounded by
    # one quantization step, NOT growing with rounds
    assert resid.max() < 0.1, resid.max()


def test_shapes_nonmultiple_of_block():
    g = jax.random.normal(jax.random.key(2), (3, 7, 11))
    q, s = quantize_int8(g)
    deq = dequantize_int8(q, s, g.shape)
    assert deq.shape == g.shape
    assert float(jnp.abs(deq - g).max()) < 0.1

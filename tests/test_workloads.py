"""Workload spec validation, lowering, zipf_cdf hardening, phase padding."""
import numpy as np
import pytest

from repro.workloads import (Phase, Workload, from_simconfig, lower, mixed,
                             pad_phases, resolve_locality, zipf_cdf)


# -- spec validation --------------------------------------------------------


def test_workload_validation():
    with pytest.raises(ValueError, match="alg"):
        Workload("qlock", 2, 2, 8)
    with pytest.raises(ValueError, match="probability"):
        Workload("alock", 2, 2, 8, locality=1.5)
    with pytest.raises(ValueError, match="per-thread locality"):
        Workload("alock", 2, 2, 8, locality=(0.5, 0.5, 0.5))  # needs T=4
    with pytest.raises(ValueError, match="zipf_s"):
        Workload("alock", 2, 2, 8, zipf_s=-1.0)
    with pytest.raises(ValueError, match="think"):
        Workload("alock", 2, 2, 8, think="warp")
    with pytest.raises(ValueError, match="b_init"):
        Workload("alock", 2, 2, 8, b_init=(1, 2, 3))
    with pytest.raises(ValueError, match="sum to 1"):
        Workload("alock", 2, 2, 8, phases=(Phase(frac=0.5),))
    with pytest.raises(ValueError, match="every node down"):
        Workload("alock", 2, 2, 8,
                 phases=(Phase(frac=1.0, down_nodes=(0, 1)),))
    with pytest.raises(ValueError, match="down_nodes"):
        Workload("alock", 2, 2, 8, phases=(Phase(frac=1.0,
                                                 down_nodes=(7,)),))
    with pytest.raises(ValueError, match="Phase.frac"):
        Phase(frac=0.0)


def test_workload_hashable_dict_key():
    w1 = Workload("alock", 2, 2, 8, locality=(0.5, 1.0, 0.8, 0.2),
                  phases=(Phase(frac=0.5), Phase(frac=0.5, zipf_s=2.0)))
    w2 = Workload("alock", 2, 2, 8, locality=(0.5, 1.0, 0.8, 0.2),
                  phases=(Phase(frac=0.5), Phase(frac=0.5, zipf_s=2.0)))
    assert w1 == w2 and hash(w1) == hash(w2)
    assert {w1: 1}[w2] == 1
    assert w1.replace(seed=3) != w1


def test_mixed_locality_resolution():
    row = resolve_locality(mixed(local=0.9, frac=0.5, rest=0.1),
                           n_nodes=2, tpn=4)
    np.testing.assert_allclose(
        row, np.float32([0.9, 0.9, 0.1, 0.1] * 2))
    full = resolve_locality(0.7, n_nodes=2, tpn=2)
    np.testing.assert_allclose(full, np.float32([0.7] * 4))


# -- lowering ---------------------------------------------------------------


def test_lower_edges_and_overrides():
    w = Workload("alock", 2, 2, 8, locality=0.9, zipf_s=0.5, think="short",
                 phases=(Phase(frac=0.3),
                         Phase(frac=0.4, zipf_s=2.0, think="long",
                               down_nodes=(1,)),
                         Phase(frac=0.3, locality=0.2)))
    lw = lower(w, n_events=1000)
    o = lw.operands
    assert o.n_phases == 3
    np.testing.assert_array_equal(o.edges, [0, 300, 700])
    # inherit vs override
    np.testing.assert_allclose(o.locality[0], np.float32([0.9] * 4))
    np.testing.assert_allclose(o.locality[2], np.float32([0.2] * 4))
    np.testing.assert_array_equal(o.zcdf[1], zipf_cdf(4, 2.0))
    np.testing.assert_array_equal(o.zcdf[2], zipf_cdf(4, 0.5))
    assert o.think_ns[1] == 16 * o.think_ns[0]   # long(4.0) vs short(0.25)
    np.testing.assert_array_equal(o.active[1], [1, 1, 0, 0])
    np.testing.assert_array_equal(o.active[0], [1, 1, 1, 1])
    assert lw.shape_key == ("alock", 4, 2, 8, 1000, 0)


def test_lower_rejects_uneven_partition():
    with pytest.raises(ValueError, match="partition"):
        lower(Workload("alock", 3, 2, 8), n_events=10)


def test_lower_rejects_collapsed_phase_program():
    """A phase that rounds to zero events must be an error, not a silent
    drop (the rejoin bump would read the dropped phase's active mask)."""
    w = Workload("alock", 2, 2, 8,
                 phases=(Phase(frac=0.3), Phase(frac=0.4, down_nodes=(1,)),
                         Phase(frac=0.3)))
    with pytest.raises(ValueError, match="strictly increasing"):
        lower(w, n_events=2)
    assert lower(w, n_events=10).operands.edges.tolist() == [0, 3, 7]


def test_pad_phases_shapes_and_shrink_error():
    o = lower(Workload("alock", 2, 2, 8), n_events=100).operands
    p3 = pad_phases(o, 3)
    assert p3.locality.shape == (3, 4) and p3.edges.shape == (3,)
    assert p3.b_init.shape == (3, 2) and p3.cost_rows.shape == (3, 8)
    assert (p3.edges[1:] == np.iinfo(np.int32).max).all()
    np.testing.assert_array_equal(p3.locality[2], o.locality[0])
    np.testing.assert_array_equal(p3.b_init[2], o.b_init[0])
    np.testing.assert_array_equal(p3.cost_rows[2], o.cost_rows[0])
    with pytest.raises(ValueError, match="shrink"):
        pad_phases(p3, 1)


def test_from_simconfig_roundtrip_fields():
    from repro.core.sim import SimConfig
    cfg = SimConfig("mcs", 3, 2, 6, 0.85, (2, 3), seed=9, zipf_s=1.2)
    w = from_simconfig(cfg)
    assert (w.alg, w.n_nodes, w.threads_per_node, w.n_locks) == \
        ("mcs", 3, 2, 6)
    assert w.locality == 0.85 and w.b_init == (2, 3)
    assert w.seed == 9 and w.zipf_s == 1.2 and w.phases == ()


# -- zipf_cdf hardening (satellite) -----------------------------------------


def test_zipf_cdf_rejects_bad_skew():
    for bad in (float("nan"), float("inf"), -float("inf"), -0.5):
        with pytest.raises(ValueError, match="finite"):
            zipf_cdf(8, bad)
    with pytest.raises(ValueError, match="at least one lock"):
        zipf_cdf(0, 1.0)


def test_zipf_cdf_s0_exactly_uniform_float32():
    for kpn in (3, 5, 8, 100):
        np.testing.assert_array_equal(
            zipf_cdf(kpn, 0.0),
            (np.arange(1, kpn + 1) / kpn).astype(np.float32))
    for kpn in (7, 8, 100, 1000):
        for s in (0.0, 1.5, 4.0):
            cdf = zipf_cdf(kpn, s)
            assert cdf.dtype == np.float32
            assert cdf[-1] == np.float32(1.0)

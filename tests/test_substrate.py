"""Optimizer, data pipeline, checkpointing, MoE layer, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import TRAIN_RULES, pspec, \
    rules_for_shape
from repro.train import checkpoint as ckpt
from repro.train.data import SyntheticLM, global_batch
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state, \
    schedule


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([3.0, -2.0, 1.0])}
    opt = init_opt_state(params)
    cfg = OptConfig(lr=0.05, warmup_steps=5, total_steps=400,
                    weight_decay=0.0, clip_norm=10.0)
    for step in range(400):
        g = {"w": 2 * params["w"]}
        params, opt, m = adamw_update(cfg, params, g, opt,
                                      jnp.asarray(step, jnp.int32))
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_schedule_warmup_and_cosine():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                    min_lr_frac=0.1)
    s0 = float(schedule(cfg, jnp.asarray(0)))
    s9 = float(schedule(cfg, jnp.asarray(9)))
    s100 = float(schedule(cfg, jnp.asarray(99)))
    assert s0 < s9 <= cfg.lr * 1.01
    assert abs(s100 - 1e-4) < 2e-5


def test_grad_clip_applied():
    params = {"w": jnp.zeros(3)}
    opt = init_opt_state(params)
    cfg = OptConfig(lr=1.0, warmup_steps=0, total_steps=10, clip_norm=1.0,
                    weight_decay=0.0)
    _, _, m = adamw_update(cfg, params, {"w": jnp.full(3, 100.0)}, opt,
                           jnp.asarray(5, jnp.int32))
    assert float(m["grad_norm"]) > 100


def test_data_deterministic_and_addressable():
    ds = SyntheticLM(vocab=101, seq_len=16, batch_per_shard=2, seed=3)
    b1 = ds.batch(shard=1, step=7)
    b2 = ds.batch(shard=1, step=7)
    assert (b1["tokens"] == b2["tokens"]).all()
    b3 = ds.batch(shard=2, step=7)
    assert (b1["tokens"] != b3["tokens"]).any()
    # labels follow the affine-mod process over [0, modulus)
    t, l = b1["tokens"], b1["labels"]
    diff = (l - (3 * t + 7)) % ds.modulus
    assert set(np.unique(diff)) <= {0, 1, 2}
    assert b1["tokens"].max() < ds.modulus
    g = global_batch(ds, [0, 1], 3)
    assert g["tokens"].shape == (4, 16)


def test_checkpoint_roundtrip_bf16(tmp_path):
    state = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
             "b": {"c": jnp.ones(4, jnp.int32)},
             "step": jnp.asarray(17, jnp.int32)}
    assert ckpt.save_checkpoint(str(tmp_path), 17, state)
    step, got = ckpt.restore_checkpoint(str(tmp_path), state)
    assert step == 17
    assert got["a"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(got["a"], np.float32),
                                  np.asarray(state["a"], np.float32))
    assert int(got["step"]) == 17


def test_checkpoint_latest_and_incomplete_ignored(tmp_path):
    state = {"x": jnp.zeros(2)}
    ckpt.save_checkpoint(str(tmp_path), 10, state)
    ckpt.save_checkpoint(str(tmp_path), 20, state)
    # a torn write without manifest must be ignored
    os.makedirs(tmp_path / "step_00000030")
    assert ckpt.latest_step(str(tmp_path)) == 20


def test_async_checkpointer(tmp_path):
    c = ckpt.AsyncCheckpointer(str(tmp_path))
    c.save(5, {"x": jnp.ones(3)})
    c.wait()
    assert ckpt.latest_step(str(tmp_path)) == 5


# ---------------------------------------------------------------------------
# sharding rules


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_pspec_divisibility_fallback():
    import jax
    mesh = jax.make_mesh((1,), ("model",))  # single device: size-1 axes
    p = pspec((40, 64), ("heads", "ffn"), TRAIN_RULES, mesh, tensor="t")
    assert p is not None


def test_rules_for_shape_kinds():
    r = rules_for_shape("train", kv_divisible=False)
    assert r.get("embed") == "data"
    r2 = rules_for_shape("decode", kv_divisible=False)
    assert r2.get("embed") is None          # TP-only weights for serving
    assert r2.get("cache_seq") == "model"   # kv heads don't divide
    r3 = rules_for_shape("decode", kv_divisible=True)
    assert r3.get("cache_heads") == "model"
    r4 = rules_for_shape("long_decode", kv_divisible=False)
    assert r4.get("cache_seq") == ("data", "model")


# ---------------------------------------------------------------------------
# MoE against a dense oracle


def test_moe_matches_dense_oracle():
    from repro.configs import get_config
    from repro.models import layers as L
    from repro.models.params import init_tree

    cfg = get_config("mixtral-8x7b").tiny()
    spec = cfg.groups[0][0][0]
    p = init_tree(L.moe_specs(cfg, spec), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))
    ctx = L.Ctx("full", jnp.zeros((2, 8), jnp.int32), None, None, None)
    y, aux = L.moe_apply(cfg, spec, p, x, ctx)
    # dense oracle: per-token top-k experts, no capacity
    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / gate.sum(-1, keepdims=True)   # mixtral normalizes
    y_ref = jnp.zeros_like(x)
    for b in range(2):
        for s in range(8):
            acc = jnp.zeros(cfg.d_model)
            for j in range(cfg.top_k):
                e = int(idx[b, s, j])
                xi = x[b, s]
                h = jax.nn.silu(xi @ p["w1"][e]) * (xi @ p["w3"][e])
                acc = acc + gate[b, s, j] * (h @ p["w2"][e])
            y_ref = y_ref.at[b, s].set(acc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4,
                               rtol=2e-3)
    assert 0.5 < float(aux) < 4.0   # load-balance loss near E*mean≈1

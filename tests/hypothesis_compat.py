"""Import-safe stand-ins for hypothesis so property tests degrade to skips.

Usage (instead of ``from hypothesis import given, settings, strategies``):

    from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

When hypothesis is installed these are the real objects. When it is not,
``@given(...)`` replaces the test with a no-arg function marked skip, and
``st.<anything>(...)`` returns inert placeholders, so modules still import
and the non-property tests in them run offline.
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _Strategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()

    def settings(*_a, **_k):
        return lambda f: f

    def given(*_a, **_k):
        def deco(f):
            def shim():
                pass
            shim.__name__ = f.__name__
            shim.__doc__ = f.__doc__
            return pytest.mark.skip(
                reason="hypothesis not installed")(shim)
        return deco

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

"""Dry-run machinery, exercised in a subprocess (it forces 512 host devices;
the test session must keep seeing 1). Also covers hlo_analysis loop
accounting and the budgeted cohort-collective programs on a multi-pod mesh.
"""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

# On jax<0.5 (experimental shard_map, partial-auto via `auto=`), taking
# grad through a partial-manual shard_map CHECK-crashes XLA-CPU (process
# abort, not a catchable error) — same blocked-path family as the
# grad(scan(shard_map)) crash documented in configs/base.py. The budgeted
# cohort-collective test needs exactly that path, so gate it on the public
# jax.shard_map API.
requires_partial_shard_map_grad = pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="grad(partial-auto shard_map) CHECK-crashes XLA-CPU on "
           "jax<0.5's experimental shard_map")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
ENV.pop("JAX_PLATFORMS", None)


def run_py(code: str, timeout=560):
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=ENV,
                          timeout=timeout)


def test_hlo_analysis_loop_accounting():
    r = run_py("""
        import os
        os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
        import jax, jax.numpy as jnp
        from repro.launch.hlo_analysis import analyze_program
        def f(x, ws):
            def body(c, w):
                return jnp.tanh(c @ w), None
            return jax.lax.scan(body, x, ws)[0]
        x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        ws = jax.ShapeDtypeStruct((12, 256, 256), jnp.float32)
        c = jax.jit(f).lower(x, ws).compile()
        a = analyze_program(c.as_text(), 1)
        exp = 12 * 2 * 256**3
        assert abs(a['flops'] / exp - 1) < 0.01, a
        print('OK')
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_collective_parse_on_sharded_program():
    r = run_py("""
        import os
        os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import parse_collectives
        mesh = jax.make_mesh((8,), ('d',))
        sh = NamedSharding(mesh, P('d'))
        def f(x):
            return x.sum()   # cross-device reduction -> all-reduce
        c = jax.jit(f, in_shardings=sh).lower(
            jax.ShapeDtypeStruct((64, 32), jnp.float32)).compile()
        st = parse_collectives(c.as_text(), 8)
        assert st.raw_bytes > 0, st.summary()
        kinds = set(o['kind'] for o in st.ops)
        assert 'all-reduce' in kinds or 'all-gather' in kinds, kinds
        print('OK')
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_dryrun_cell_tiny_mesh():
    """Full dry-run path (lower+compile+analysis) for one small arch on a
    512-way production mesh in a subprocess."""
    r = run_py("""
        from repro.launch.dryrun import run_cell
        rec = run_cell('whisper-base', 'decode_32k', 'single',
                       '/tmp/dryrun_test')
        assert rec['status'] == 'ok', rec.get('error', rec)
        assert rec['flops_per_chip'] > 0
        assert rec['roofline']['dominant'] in (
            'compute_s', 'memory_s', 'collective_link_s')
        print('OK')
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


@requires_partial_shard_map_grad
def test_budgeted_cohort_steps_multi_pod():
    """local_accum_step must contain NO cross-pod collectives; sync_step
    must contain the cross-pod reduction. Budget=1 equals the sync baseline
    by construction (acc mean over one microbatch)."""
    r = run_py("""
        import os
        os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import model as M
        from repro.models.params import init_tree
        from repro.parallel.collectives import make_budgeted_steps
        from repro.train.optimizer import OptConfig, init_opt_state
        from repro.train.train_step import make_train_step

        cfg = get_config('yi-9b').tiny()
        mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
        params = init_tree(M.model_specs(cfg), jax.random.key(0))
        opt_cfg = OptConfig(lr=1e-2, warmup_steps=0, total_steps=10,
                            weight_decay=0.0)
        opt = init_opt_state(params)
        init_acc, local_step, sync_step, sync_comp = make_budgeted_steps(
            cfg, opt_cfg, mesh, n_pod=2)
        B, S = 4, 16
        toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab,
                                  jnp.int32)
        batch_pod = {'tokens': toks.reshape(2, 2, S),
                     'labels': toks.reshape(2, 2, S)}
        with mesh:
            acc0 = init_acc(params)
            acc1, loss = jax.jit(local_step)(params, acc0, batch_pod)
            p2, o2, acc2, m = jax.jit(sync_step)(
                params, opt, acc1, jnp.asarray(0, jnp.int32), 1)
        # equivalence with the plain synchronous step on the same batch
        plain = make_train_step(cfg, opt_cfg)
        batch = {'tokens': toks, 'labels': toks}
        p1, o1, m1 = jax.jit(plain)(params, init_opt_state(params), batch,
                                    jnp.asarray(0, jnp.int32))
        import numpy as np
        d = max(float(jnp.abs(a.astype(jnp.float32) -
                              b.astype(jnp.float32)).max())
                for a, b in zip(jax.tree_util.tree_leaves(p1),
                                jax.tree_util.tree_leaves(p2)))
        assert d < 2e-3, d   # reduction-order noise after one opt step
        # the sync program must reduce across pods: lower against the
        # pod-sharded accumulator local_step produced
        with mesh:
            lowered = jax.jit(sync_step).lower(params, opt, acc1,
                                               jnp.asarray(0, jnp.int32), 1)
            txt = lowered.compile().as_text()
        assert ('all-reduce' in txt or 'reduce-scatter' in txt
                or 'all-gather' in txt), txt[:2000]
        print('OK', d)
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr

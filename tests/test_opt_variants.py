"""Beyond-paper optimization paths must be numerically exact vs baselines:
banded window attention, MLA head padding, expert-parallel MoE."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import layers as L
from repro.models.params import init_tree


def test_banded_matches_masked_sdpa():
    key = jax.random.key(0)
    B, S, K, R, hd = 2, 96, 2, 2, 8
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, S, K, R, hd))
    k = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, hd))
    v = jax.random.normal(jax.random.fold_in(key, 3), (B, S, K, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    for window, qc in ((16, 16), (8, 32)):
        o1 = L.banded_sdpa(q, k, v, window=window, q_chunk=qc)
        o2 = L._sdpa(q, k, v, L._mask(pos, jnp.arange(S), causal=True,
                                      window=window))
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   atol=3e-5, rtol=3e-5)

        def f1(q, k, v):
            return L.banded_sdpa(q, k, v, window=window, q_chunk=qc).sum()

        def f2(q, k, v):
            m = L._mask(pos, jnp.arange(S), causal=True, window=window)
            return L._sdpa(q, k, v, m).sum()
        g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=3e-5, rtol=3e-5)


def test_banded_config_path_matches_flash():
    """gemma3 tiny with banded_window_attn on == off (same logits)."""
    from repro.models import model as M
    base = dataclasses.replace(get_config("gemma3-1b").tiny(),
                               blockwise_min_seq=8, q_chunk=8)
    banded = dataclasses.replace(base, banded_window_attn=True)
    params = init_tree(M.model_specs(base), jax.random.key(0))
    batch = {"tokens": jnp.ones((2, 64), jnp.int32) * 3,
             "labels": jnp.ones((2, 64), jnp.int32)}
    l0, _, _ = M.forward(base, params, batch)
    l1, _, _ = M.forward(banded, params, batch)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=2e-4,
                               rtol=2e-4)


def test_mla_head_padding_shapes():
    cfg = get_config("minicpm3-4b").tiny()
    padded = dataclasses.replace(cfg, pad_heads_to=8)
    spec = cfg.groups[0][0][0]
    p = L.mla_specs(padded, spec)
    assert p["wuq"].shape[1] == 8
    assert p["wo"].shape[0] == 8
    # forward still runs and is finite
    params = init_tree(p, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    ctx = L.Ctx("full", jnp.broadcast_to(jnp.arange(16), (2, 16)), None,
                None, None)
    y, _ = L.mla_apply(padded, spec, params, x, ctx)
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())


def test_moe_expert_padding_inert():
    """Padded experts are never routed to; outputs match the unpadded MoE
    when real-expert weights coincide (single-device path: EP off)."""
    cfg = dataclasses.replace(get_config("qwen2-moe-a2.7b").tiny(),
                              n_experts=6, top_k=2, capacity_factor=6.0)
    cfg_p = dataclasses.replace(cfg, pad_experts_to=8)
    spec = cfg.groups[0][0][0]
    p = init_tree(L.moe_specs(cfg, spec), jax.random.key(0))
    pp = init_tree(L.moe_specs(cfg_p, spec), jax.random.key(0))
    for w in ("w1", "w3", "w2"):
        pp[w] = pp[w].at[:6].set(p[w])
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    ctx = L.Ctx("full", jnp.zeros((2, 16), jnp.int32), None, None, None)
    y0, _ = L.moe_apply(cfg, spec, p, x, ctx)
    y1, _ = L.moe_apply(cfg_p, spec, pp, x, ctx)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=2e-5,
                               rtol=2e-5)


def test_unroll_matches_scan():
    from repro.models import model as M
    cfg = get_config("yi-9b").tiny()
    cfg_u = cfg.unroll()
    assert cfg_u.n_layers == cfg.n_layers
    params = init_tree(M.model_specs(cfg), jax.random.key(0))
    # re-layout stacked params (2-layer group) into repeat-1 groups
    pu = init_tree(M.model_specs(cfg_u), jax.random.key(0))
    flat = jax.tree_util.tree_leaves(params["dec"])
    flat_u = jax.tree_util.tree_leaves(pu["dec"])
    # same total parameter volume
    assert sum(x.size for x in flat) == sum(x.size for x in flat_u)


def test_int8_kv_cache_decode_consistency():
    """Quantized KV cache: decode matches teacher forcing within int8
    quantization tolerance; cache payload is int8."""
    from repro.models import model as M
    cfg = dataclasses.replace(get_config("yi-9b").tiny(),
                              kv_cache_int8=True)
    params = init_tree(M.model_specs(cfg), jax.random.key(1))
    B, S, E = 2, 24, 2
    toks = jax.random.randint(jax.random.key(7), (B, S + E), 0, cfg.vocab,
                              jnp.int32)
    logits_full, _, _ = M.forward(cfg, params, {"tokens": toks})
    lg, cache = M.prefill(cfg, params, {"tokens": toks[:, :S]},
                          cache_len=S + E)
    errs = [float(jnp.abs(lg - logits_full[:, S - 1]).max())]
    for i in range(E):
        lg, cache = M.decode_step(cfg, params, cache,
                                  toks[:, S + i:S + i + 1],
                                  jnp.asarray(S + i, jnp.int32))
        errs.append(float(jnp.abs(lg - logits_full[:, S + i]).max()))
    assert max(errs) < 0.5, errs
    leaf = cache[0][0]["mixer"]
    assert leaf["k"].dtype == jnp.int8 and leaf["ks"].dtype == jnp.float32

"""VMEM budget planner (`kernels/event_loop/vmem`): the bytes formula
matches the buffers the kernel actually allocates, oversize tiles
auto-shrink deterministically, impossible budgets raise an actionable
ValueError (never a Mosaic crash), and the chosen plan is reported through
``batch.exec_stats()`` — all with no TPU (interpret mode / pure python).
"""
import numpy as np
import pytest
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core import batch
from repro.core.cost_model import N_COST_ROWS
from repro.core.sim import LAT_SAMPLES, topology
from repro.kernels.event_loop import vmem
from repro.kernels.event_loop.ops import run_events, run_events_pairs
from repro.kernels.event_loop.ref import run_events_ref
from repro.workloads import Arrivals, Workload, WorkloadOperands, lower

ARGS = dict(tile=4, ev_chunk=256, T=12, N=3, K=6, P=2,
            lat_samples=LAT_SAMPLES)


def test_buffer_table_matches_kernel_allocations():
    """The documented formula, buffer for buffer: every shape in the plan
    equals the block/scratch shape ``ops.run_events`` builds for the same
    parameters (the interpret-mode allocations the acceptance criterion
    points at)."""
    for repr32 in (False, True):
        t = vmem.buffer_table(repr32=repr32, **ARGS)
        tile, ev_chunk, T, N, K, P = (ARGS["tile"], ARGS["ev_chunk"],
                                      ARGS["T"], ARGS["N"], ARGS["K"],
                                      ARGS["P"])
        # inputs: the in_specs block shapes
        assert t["in.u1"][0] == (tile, ev_chunk)
        assert t["in.locality"][0] == (tile, P * T)
        assert t["in.cost_rows"][0] == (tile, P * N_COST_ROWS)
        assert t["in.thread_node"][0] == (1, T)
        assert t["in.lock_node"][0] == (1, K)
        # outputs: one i64 ring vs an (hi, lo) i32 pair, same total bytes
        if repr32:
            assert t["out.lat.hi"][0] == (tile, LAT_SAMPLES)
            assert (t["out.lat.hi"][1] + t["out.lat.lo"][1]
                    == tile * LAT_SAMPLES * 8)
            assert "out.lat" not in t
        else:
            assert t["out.lat"] == ((tile, LAT_SAMPLES),
                                    tile * LAT_SAMPLES * 8)
        # scratch: semantic i32 + clock buffers
        assert t["scr.tail0"] == ((tile, K), tile * K * 4)
        assert t["scr.pc"] == ((tile, T), tile * T * 4)
        ready = (t["scr.ready.hi"][1] + t["scr.ready.lo"][1] if repr32
                 else t["scr.ready"][1])
        assert ready == tile * T * 8
        busy = (t["scr.busy.hi"][1] + t["scr.busy.lo"][1] if repr32
                else t["scr.busy"][1])
        assert busy == tile * N * 8
        # the double-buffered event streams carry the pipeline factor
        assert t["in.u1"][1] == tile * ev_chunk * 4 * vmem.PIPELINE_FACTOR
        # and the plan total is exactly the sum of the table
        plan = vmem.plan_vmem(repr32=repr32, **ARGS)
        assert plan.total_bytes == sum(b for _, b in t.values())


def test_plan_matches_measured_pallas_buffers(monkeypatch):
    """Measure, don't restate: intercept ``pl.pallas_call`` and diff the
    planner's table against the in/out/scratch buffers the kernel
    *actually* allocates in interpret mode — name for name, shape for
    shape, byte for byte."""
    from repro.kernels.event_loop import ops as el_ops
    captured = {}
    real = el_ops.pl.pallas_call

    def spy(kernel, **kw):
        captured.update(kw)
        return real(kernel, **kw)

    monkeypatch.setattr(el_ops.pl, "pallas_call", spy)
    wl, tn, ln, ev = _replicas("alock", ev=300, B=5)
    run_events_pairs("alock", 4, 2, 8, ev, wl, tn, ln, interpret=True,
                     tile=2, ev_chunk=128, lat_samples=512)
    plan = vmem.last_plan()
    t = plan.breakdown          # insertion-ordered: in.* / out.* / scr.*

    def names(prefix):
        return [k for k in t if k.startswith(prefix)]

    assert [t[k][0] for k in names("in.")] == \
        [s.block_shape for s in captured["in_specs"]]
    assert [t[k][0] for k in names("out.")] == \
        [s.block_shape for s in captured["out_specs"]]
    assert [t[k][0] for k in names("scr.")] == \
        [tuple(s.shape) for s in captured["scratch_shapes"]]
    # bytes = prod(shape) x 4 (all buffers are f32/i32 pairs under the
    # native representation), x2 for the double-buffered event streams
    for k, (shape, nbytes) in t.items():
        factor = (vmem.PIPELINE_FACTOR
                  if k in ("in.u1", "in.r2", "in.r3") else 1)
        assert nbytes == int(np.prod(shape)) * 4 * factor, k


def test_open_loop_plan_matches_measured_pallas_buffers(monkeypatch):
    """Same measurement, open loop: an ``R > 0`` run must surface the
    arrival rows, the per-request outputs and the dispatch scratch in the
    planner's table at their exact binding positions (the vmem-consistency
    lint diffs traced kernels against this order)."""
    from repro.kernels.event_loop import ops as el_ops
    captured = {}
    real = el_ops.pl.pallas_call

    def spy(kernel, **kw):
        captured.update(kw)
        return real(kernel, **kw)

    monkeypatch.setattr(el_ops.pl, "pallas_call", spy)
    arr = Arrivals(rate_per_us=2.0, max_requests=24, queue_cap=8,
                   token_rate_per_us=1.0, token_burst=4.0)
    ev = 300
    ws = [lower(Workload("alock", 2, 2, 8, locality=0.9, seed=4 + s,
                         arrivals=arr), ev) for s in range(3)]
    wl = WorkloadOperands(
        *(jnp.asarray(np.stack([np.asarray(getattr(w.operands, f))
                                for w in ws]))
          for f in WorkloadOperands._fields))
    tn, ln, _ = topology("alock", 2, 2, 8)
    run_events_pairs("alock", 4, 2, 8, ev, wl, tn, ln, interpret=True,
                     tile=2, ev_chunk=128, lat_samples=512)
    plan = vmem.last_plan()
    t = plan.breakdown
    for k in ("in.arr.hi", "in.arr.lo", "in.tok", "in.tokcum", "in.qcap",
              "out.wq.hi", "out.wq.lo", "out.soj.hi", "out.soj.lo",
              "out.rstat", "scr.curreq", "scr.arrptr", "scr.qlen"):
        assert k in t, k
    assert t["in.arr.hi"][0] == (2, 24)
    assert t["out.rstat"][0] == (2, 24)

    def names(prefix):
        return [k for k in t if k.startswith(prefix)]

    assert [t[k][0] for k in names("in.")] == \
        [s.block_shape for s in captured["in_specs"]]
    assert [t[k][0] for k in names("out.")] == \
        [s.block_shape for s in captured["out_specs"]]
    assert [t[k][0] for k in names("scr.")] == \
        [tuple(s.shape) for s in captured["scratch_shapes"]]


def test_plan_representations_cost_identical_bytes():
    """hi/lo i32 pairs occupy exactly the bytes of the i64 buffers they
    replace — switching representation must never change the footprint."""
    a = vmem.plan_vmem(repr32=False, **ARGS)
    b = vmem.plan_vmem(repr32=True, **ARGS)
    assert a.total_bytes == b.total_bytes


def test_oversize_tile_auto_shrinks_deterministically():
    kw = dict(ARGS, tile=64)
    budget = 4 * 2**20
    p1 = vmem.plan_vmem(repr32=True, budget=budget, **kw)
    p2 = vmem.plan_vmem(repr32=True, budget=budget, **kw)
    assert p1 == p2                       # deterministic
    assert p1.shrunk and p1.requested_tile == 64
    assert p1.tile < 64 and p1.total_bytes <= budget
    # halving: the next-larger tile would NOT have fit
    over = vmem.plan_vmem(repr32=True, **dict(kw, tile=p1.tile * 2))
    assert over.total_bytes > budget
    # the dict view benchmarks serialize
    d = p1.as_dict()
    assert d["shrunk"] and d["tile"] == p1.tile and d["budget"] == budget


def test_impossible_budget_raises_actionable_error():
    with pytest.raises(ValueError, match="lat_samples"):
        vmem.plan_vmem(repr32=True, budget=10_000, **ARGS)
    # bad arguments are real errors too
    with pytest.raises(ValueError, match="tile"):
        vmem.plan_vmem(repr32=True, **dict(ARGS, tile=0))
    with pytest.raises(ValueError, match="budget"):
        vmem.plan_vmem(repr32=True, budget=0, **ARGS)


def _replicas(alg="alock", ev=700, B=1):
    ws = [lower(Workload(alg, 2, 2, 8, locality=0.9, seed=4 + s), ev)
          for s in range(B)]
    wl = WorkloadOperands(
        *(jnp.asarray(np.stack([np.asarray(getattr(w.operands, f))
                                for w in ws]))
          for f in WorkloadOperands._fields))
    tn, ln, _ = topology(alg, 2, 2, 8)
    return wl, tn, ln, ev


def test_budgeted_run_shrinks_tile_and_stays_bitwise():
    """An explicit budget that cannot hold the requested tile must shrink
    it — and the shrunk run stays bitwise-equal to the oracle (auto-shrink
    is never allowed to become a silent wrong answer)."""
    wl, tn, ln, ev = _replicas(B=6)
    lat_samples = 1024
    # 6 replicas at lat_samples=1024 / ev_chunk=128 cost ~12 KiB per tile
    # row; 24 KiB forces the 6 -> 3 -> 1 halving path
    budget = 24 * 1024
    with enable_x64():
        ref = [np.asarray(r) for r in
               run_events_ref("alock", 4, 2, 8, ev, wl, tn, ln,
                              lat_samples=lat_samples)]
        out = run_events("alock", 4, 2, 8, ev, wl, tn, ln, interpret=True,
                         representation="i32pair", tile=8, ev_chunk=128,
                         lat_samples=lat_samples, vmem_budget=budget)
    plan = vmem.last_plan()
    assert plan is not None and plan.shrunk
    assert plan.requested_tile == 6 and plan.tile == 1
    assert plan.total_bytes <= budget
    for a, b in zip(ref, out):
        np.testing.assert_array_equal(a, np.asarray(b))


def test_plan_surfaces_through_exec_stats():
    batch.reset_exec_stats()
    assert batch.exec_stats()["vmem_plan"] is None
    wl, tn, ln, ev = _replicas("mcs")
    run_events_pairs("mcs", 4, 2, 8, ev, wl, tn, ln, interpret=True,
                     lat_samples=256, ev_chunk=256)
    st = batch.exec_stats()
    assert st["vmem_plan"] is not None
    assert st["vmem_plan"]["representation"] == "i32pair"
    assert st["vmem_plan"]["lat_samples"] == 256
    batch.reset_exec_stats()
    assert batch.exec_stats()["vmem_plan"] is None


def test_impossible_budget_through_run_events():
    """The planner error reaches the caller as ValueError, not a trace-
    or Mosaic-level failure."""
    wl, tn, ln, ev = _replicas()
    with pytest.raises(ValueError, match="budget"):
        run_events_pairs("alock", 4, 2, 8, ev, wl, tn, ln, interpret=True,
                         vmem_budget=1024)


def test_plan_for_run_minimizes_edge_padding():
    """The grid keeps its tile count but sheds dead edge rows: B=9 at a
    requested tile of 8 runs two tiles of 5 (pad 1), not 8+1 (pad 7);
    exact divisors and B <= tile stay untouched; and the VMEM halving
    composes with the minimized tile, not the requested one."""
    from repro.kernels.event_loop.ops import plan_for_run
    shape = dict(T=12, N=3, K=6)
    assert plan_for_run(9, 2, 64, tile=8, interpret=True,
                        **shape).tile == 5
    assert plan_for_run(5, 2, 64, tile=2, interpret=True,
                        **shape).tile == 2
    assert plan_for_run(9, 2, 64, tile=3, interpret=True,
                        **shape).tile == 3
    assert plan_for_run(6, 2, 64, tile=6, interpret=True,
                        **shape).tile == 6
    # budget pressure: 5 does not fit, one halving lands on 2 (which the
    # budget below is sized to fit exactly)
    fit2 = vmem.plan_vmem(tile=2, ev_chunk=64, P=2, repr32=True,
                          lat_samples=LAT_SAMPLES, **shape).total_bytes
    p = plan_for_run(9, 2, 64, tile=8, interpret=True,
                     representation="i32pair", vmem_budget=fit2, **shape)
    assert (p.requested_tile, p.tile, p.shrunk) == (5, 2, True)

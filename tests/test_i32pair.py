"""Property tests for the hi/lo i32 clock helpers (`kernels/event_loop/
i32pair`): every operation round-trips against an int64 reference across
carry boundaries, INT32_MAX±1, and the parked-thread ``never`` sentinel.

Runs with x64 off (the whole point of the representation); int64
references are computed host-side in numpy. Hypothesis legs degrade to
skips when hypothesis is absent (``hypothesis_compat``); the deterministic
edge-case legs below always run.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.kernels.event_loop import i32pair as p32

I64_MAX = np.iinfo(np.int64).max
NEVER = I64_MAX

# the boundary values the kernel actually crosses: zero, low-word
# carry edges, hi-word sign edges, INT32_MAX±1 and the never sentinel
EDGES = np.int64([0, 1, -1, 2**31 - 2, 2**31 - 1, 2**31, 2**31 + 1,
                  2**32 - 1, 2**32, 2**32 + 1, -2**31, -2**31 - 1,
                  -2**32, 3 * 10**18, -3 * 10**18, NEVER, -NEVER - 1])


def to_pair(x):
    hi, lo = p32.unpack_np(np.asarray(x, np.int64))
    return (jnp.asarray(hi), jnp.asarray(lo))


def from_pair(p):
    return p32.pack_np(np.asarray(p[0]), np.asarray(p[1]))


def _pairs_grid():
    """Every ordered pair of edge values — 17 x 17 combinations."""
    a = np.repeat(EDGES, len(EDGES))
    b = np.tile(EDGES, len(EDGES))
    return a, b


def test_pack_unpack_round_trip_edges():
    np.testing.assert_array_equal(from_pair(to_pair(EDGES)), EDGES)


def test_never_sentinel_is_i64_max():
    assert p32.pack_np(*p32.NEVER) == NEVER
    pe = to_pair(EDGES)
    is_never = np.asarray(p32.peq(pe, p32.NEVER))
    np.testing.assert_array_equal(is_never, EDGES == NEVER)


def test_add_sub_carry_edges():
    a, b = _pairs_grid()
    with np.errstate(over="ignore"):
        np.testing.assert_array_equal(
            from_pair(p32.padd(to_pair(a), to_pair(b))), a + b)
        np.testing.assert_array_equal(
            from_pair(p32.psub(to_pair(a), to_pair(b))), a - b)


def test_add_i32_both_signs_across_carry():
    base = np.int64([2**32 - 1, 2**32, -1, 0, 2**31 - 1, NEVER - 1])
    for d in (-3, -1, 0, 1, 3, 2**31 - 1, -2**31):
        got = from_pair(p32.padd_i32(to_pair(base), jnp.int32(d)))
        np.testing.assert_array_equal(got, base + d)


def test_compare_edges():
    a, b = _pairs_grid()
    pa, pb = to_pair(a), to_pair(b)
    np.testing.assert_array_equal(np.asarray(p32.plt(pa, pb)), a < b)
    np.testing.assert_array_equal(np.asarray(p32.ple(pa, pb)), a <= b)
    np.testing.assert_array_equal(np.asarray(p32.peq(pa, pb)), a == b)
    np.testing.assert_array_equal(from_pair(p32.pmin2(pa, pb)),
                                  np.minimum(a, b))
    np.testing.assert_array_equal(from_pair(p32.pmax2(pa, pb)),
                                  np.maximum(a, b))


def test_argmin_and_reductions_with_mask_and_ties():
    rng = np.random.default_rng(7)
    m = rng.choice(EDGES, size=(32, 16))
    m[0] = m[0][0]                       # full-row tie -> index 0
    m[1, 3] = m[1, 7] = np.int64(5)      # duplicate min -> first index
    mask = rng.integers(0, 2, m.shape).astype(bool)
    mask[2] = False                      # all-masked row -> index 0
    mask[:, 5] = True
    pm, jmask = to_pair(m), jnp.asarray(mask)
    filled = np.where(mask, m, NEVER)
    np.testing.assert_array_equal(
        np.asarray(p32.argmin_masked(pm, jmask)),
        np.argmin(filled, axis=1))
    np.testing.assert_array_equal(np.asarray(p32.argmin_masked(pm)),
                                  np.argmin(m, axis=1))
    np.testing.assert_array_equal(
        from_pair(p32.reduce_min_masked(pm, jmask)),
        np.min(filled, axis=1))
    np.testing.assert_array_equal(from_pair(p32.reduce_max(pm)),
                                  np.max(m, axis=1))


def test_mod_pow2_round_trip():
    v = np.abs(np.concatenate([EDGES[:-2], np.int64([2**33 + 70])]))
    for m in (1, 64, 1 << 15):
        np.testing.assert_array_equal(
            np.asarray(p32.mod_pow2(to_pair(v), m)), v % m)
    with pytest.raises(ValueError):
        p32.mod_pow2(to_pair(v), 48)


def test_gather_one_hot():
    rng = np.random.default_rng(3)
    m = rng.choice(EDGES, size=(8, 6))
    idx = rng.integers(0, 6, 8)
    oh = jnp.asarray(np.arange(6)[None, :] == idx[:, None])
    np.testing.assert_array_equal(from_pair(p32.pgather(oh, to_pair(m))),
                                  m[np.arange(8), idx])


# -- hypothesis legs (skip cleanly when hypothesis is absent) ---------------

BOUND = 2**62        # keep a+b inside int64 so the reference never wraps
i64s = st.lists(st.integers(min_value=-BOUND, max_value=BOUND - 1),
                min_size=1, max_size=40)


@settings(max_examples=200, deadline=None)
@given(i64s, i64s)
def test_prop_add_sub_compare(xs, ys):
    n = min(len(xs), len(ys))
    a = np.asarray(xs[:n], np.int64)
    b = np.asarray(ys[:n], np.int64)
    pa, pb = to_pair(a), to_pair(b)
    np.testing.assert_array_equal(from_pair(p32.padd(pa, pb)), a + b)
    np.testing.assert_array_equal(from_pair(p32.psub(pa, pb)), a - b)
    np.testing.assert_array_equal(np.asarray(p32.plt(pa, pb)), a < b)
    np.testing.assert_array_equal(np.asarray(p32.ple(pa, pb)), a <= b)
    np.testing.assert_array_equal(np.asarray(p32.peq(pa, pb)), a == b)


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(min_value=-BOUND, max_value=BOUND),
                min_size=2, max_size=24),
       st.integers(min_value=0, max_value=2**24))
def test_prop_argmin_matches_i64(xs, maskbits):
    a = np.asarray(xs, np.int64).reshape(1, -1)
    mask = np.asarray([(maskbits >> i) & 1 for i in range(a.shape[1])],
                      bool).reshape(1, -1)
    if not mask.any():
        mask[0, 0] = True
    pa = to_pair(a)
    np.testing.assert_array_equal(
        np.asarray(p32.argmin_masked(pa, jnp.asarray(mask))),
        np.argmin(np.where(mask, a, NEVER), axis=1))
    np.testing.assert_array_equal(np.asarray(p32.argmin_masked(pa)),
                                  np.argmin(a, axis=1))


@settings(max_examples=100, deadline=None)
@given(st.integers(min_value=0, max_value=2**62),
       st.integers(min_value=0, max_value=20))
def test_prop_mod_pow2(v, log2m):
    m = 1 << log2m
    assert int(np.asarray(p32.mod_pow2(to_pair(np.int64([v])), m))[0]) \
        == v % m


def test_hypothesis_presence_marker():
    """Document which mode this run exercised (both are valid)."""
    assert HAVE_HYPOTHESIS in (True, False)

"""Event-loop Pallas kernel vs the XLA oracle (bitwise), sharded/chunked
sweep vs the single-dispatch layout (bitwise), and the workload-draw
satellites (Zipf CDF operand, topology ValueError)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core import batch
from repro.core.sim import SimConfig, simulate, topology, zipf_cdf

EV = 1500


def _assert_same(rx, rp):
    assert rx.ops == rp.ops
    assert rx.sim_ns == rp.sim_ns
    assert rx.reacquires == rp.reacquires
    assert rx.passes == rp.passes
    np.testing.assert_array_equal(np.asarray(rx.lat_ns),
                                  np.asarray(rp.lat_ns))
    np.testing.assert_array_equal(np.asarray(rx.per_thread_ops),
                                  np.asarray(rp.per_thread_ops))


@pytest.mark.parametrize("alg", ["alock", "spinlock", "mcs"])
@pytest.mark.parametrize("loc", [0.85, 1.0])
def test_pallas_simulate_bitwise_matches_xla(alg, loc):
    """The tentpole contract: same (config, seed) -> bitwise-identical
    done/lat/t_end through the Pallas kernel (interpret mode on CPU)."""
    cfg = SimConfig(alg, 2, 2, 8, loc, (2, 3), seed=7)
    _assert_same(simulate(cfg, n_events=EV, backend="xla"),
                 simulate(cfg, n_events=EV, backend="pallas"))


def test_pallas_bitwise_with_zipf_and_multi_node():
    cfg = SimConfig("alock", 3, 4, 6, 0.9, (5, 20), seed=3, zipf_s=1.2)
    _assert_same(simulate(cfg, n_events=EV, backend="xla"),
                 simulate(cfg, n_events=EV, backend="pallas"))


def _ragged_operands(B, ev):
    """A B-replica, 2-phase operand set with per-thread locality, a downed
    node, doubled phase-2 costs and fail-slow node multipliers — the
    nastiest shape the kernel's ragged tiling has to survive."""
    from repro.workloads import WorkloadOperands
    alg, N, tpn, K = "alock", 3, 4, 6
    T, P = N * tpn, 2
    tn, ln, costs = topology(alg, N, tpn, K)
    rng = np.random.default_rng(0)
    loc = rng.uniform(0.3, 1.0, (B, P, T)).astype(np.float32)
    zc = np.stack([[zipf_cdf(K // N, s) for s in row]
                   for row in rng.uniform(0.0, 2.0, (B, P))])
    active = np.ones((B, P, T), np.int32)
    active[:, 1, :tpn] = 0          # node 0 down in the second phase
    # per-phase budgets and cost rows: the second phase re-programs the
    # budget and doubles the RNIC service cost per replica
    cst = np.tile(np.int32(costs), (B, P, 1))
    cst[:, 1, 4:6] *= 2
    # fail-slow: node 2 limps at 4x in phase 1, then node 1 at 1.5x in
    # phase 2 — exercises the (P, N) node_mult operand across the phase edge
    nm = np.ones((B, P, N), np.float32)
    nm[:, 0, 2] = 4.0
    nm[:, 1, 1] = 1.5
    wl = WorkloadOperands(
        locality=jnp.asarray(loc), zcdf=jnp.asarray(np.float32(zc)),
        edges=jnp.asarray(np.tile(np.int32([0, 600]), (B, 1))),
        think_ns=jnp.asarray(np.tile(np.int32([500, 250]), (B, 1))),
        active=jnp.asarray(active),
        b_init=jnp.asarray(np.tile(np.int32([[2, 3], [1, 5]]), (B, 1, 1))),
        seed=jnp.arange(B, dtype=jnp.int32) + 11,
        cost_rows=jnp.asarray(cst), node_mult=jnp.asarray(nm),
        # closed-loop placeholders: R == 0 arrival rows
        arr_gap_ns=jnp.zeros((B, P), jnp.float32),
        arr_edges=jnp.zeros((B, P), jnp.int32),
        arr_qcap=jnp.full((B, P), np.iinfo(np.int32).max, jnp.int32),
        arr_token=jnp.zeros((B, P, 2), jnp.float32),
        arr_fix=jnp.zeros((B, 0), jnp.int32),
        rack=jnp.tile(jnp.arange(N, dtype=jnp.int32), (B, 1)),
        read_frac=jnp.zeros((B, P, T), jnp.float32))
    return alg, T, N, K, wl, tn, ln


def test_kernel_ragged_tile_and_chunk_bitwise():
    """Replica count not a tile multiple + events not a chunk multiple must
    pad internally and still match the vmapped XLA reference exactly —
    including per-thread locality, a mid-stream phase switch (crossing a
    chunk boundary) and a downed node."""
    from repro.kernels.event_loop.ops import run_events
    from repro.kernels.event_loop.ref import run_events_ref
    ev = 1100
    alg, T, N, K, wl, tn, ln = _ragged_operands(5, ev)
    with enable_x64():
        ref = run_events_ref(alg, T, N, K, ev, wl, tn, ln)
        out = run_events(alg, T, N, K, ev, wl, tn, ln,
                         tile=2, ev_chunk=256, interpret=True)
    for a, b in zip(ref, out):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_native_grid_matches_per_replica_runs():
    """The replica axis folded into the Pallas grid (ragged tile) must be
    bitwise-equal to running every replica alone (B=1, tile=1) — replicas
    are independent lanes of one executable, and the grid fan-out may not
    couple them (the pre-fold layout was a vmap of single-replica runs)."""
    from repro.kernels.event_loop.ops import run_events
    ev, B = 700, 5           # ev crosses the 600 phase edge, ragged chunk
    alg, T, N, K, wl, tn, ln = _ragged_operands(B, ev)
    with enable_x64():
        out = run_events(alg, T, N, K, ev, wl, tn, ln,
                         tile=2, ev_chunk=256, interpret=True)
        singles = [run_events(
            alg, T, N, K, ev,
            jax.tree_util.tree_map(lambda a, i=i: a[i:i + 1], wl), tn, ln,
            tile=1, ev_chunk=256, interpret=True) for i in range(B)]
    for j, o in enumerate(out):
        cat = np.concatenate([np.asarray(s[j]) for s in singles])
        np.testing.assert_array_equal(np.asarray(o), cat)


def test_sweep_pallas_backend_matches_xla():
    cfgs = [SimConfig("mcs", 2, 2, 8, 0.9, seed=1),
            SimConfig("alock", 2, 2, 8, 0.95, (2, 3), seed=5)]
    rx = batch.sweep(cfgs, n_seeds=2, n_events=EV, backend="xla")
    rp = batch.sweep(cfgs, n_seeds=2, n_events=EV, backend="pallas")
    for a, b in zip(rx, rp):
        np.testing.assert_array_equal(a.ops, b.ops)
        np.testing.assert_array_equal(a.sim_ns, b.sim_ns)
        np.testing.assert_array_equal(a.lat_ns, b.lat_ns)
        np.testing.assert_array_equal(a.per_thread_ops, b.per_thread_ops)


def test_sweep_chunked_matches_unsharded_and_counts_dispatches():
    """A bucket larger than the chunk spills into power-of-two superchunk
    dispatches of one shared runner; results stay bitwise-equal to the
    one-dispatch layout."""
    cfgs = [SimConfig("alock", 2, 2, 8, l, (2, 3), seed=s, zipf_s=z)
            for l, s, z in ((0.9, 7, 0.0), (0.5, 1, 1.2), (0.95, 3, 0.0))]
    base = batch.sweep(cfgs, n_seeds=2, n_events=EV)      # bucket B = 6
    batch.reset_exec_stats()
    ch = batch.sweep(cfgs, n_seeds=2, n_events=EV, chunk=2)
    st = batch.exec_stats()
    # 3 units of 2 rows coalesce into superchunks [2, 1]: one dispatch
    # of 4 rows + one of 2 rows (popcount(3)), not 3 unit dispatches
    assert st["dispatches"] == 2
    for b, c in zip(base, ch):
        np.testing.assert_array_equal(b.ops, c.ops)
        np.testing.assert_array_equal(b.sim_ns, c.sim_ns)
        np.testing.assert_array_equal(b.lat_ns, c.lat_ns)
        np.testing.assert_array_equal(b.per_thread_ops, c.per_thread_ops)
    # same chunk shapes again: zero new compiles, only dispatches
    batch.reset_exec_stats()
    batch.sweep(cfgs, n_seeds=2, n_events=EV, chunk=2)
    st2 = batch.exec_stats()
    assert st2["dispatches"] == 2 and st2["compiles"] == 0


def test_sweep_three_bucket_ragged_counts_and_bitwise():
    """Dispatch/compile accounting across a 3-bucket ragged sweep under the
    pipelined path: per bucket (6, 4, 2 rows at chunk=2) the superchunk
    decomposition is [4, 2] / [4] / [2] rows — 4 dispatches, one compile
    per distinct (runner, rows) shape, zero compiles on the rerun — and
    results stay bitwise-equal to the unsharded layout."""
    ev = EV - 100     # own shape keys: no executable reuse across tests
    cfgs = ([SimConfig("alock", 2, 2, 8, l, (2, 3), seed=i)
             for i, l in enumerate((0.85, 0.9, 1.0))]
            + [SimConfig("mcs", 2, 2, 8, l, seed=3 + i)
               for i, l in enumerate((0.5, 0.95))]
            + [SimConfig("spinlock", 2, 2, 8, 0.9, seed=5)])
    assert len({batch.shape_key(c, ev) for c in cfgs}) == 3
    base = batch.sweep(cfgs, n_seeds=2, n_events=ev)
    batch.reset_exec_stats()
    ch = batch.sweep(cfgs, n_seeds=2, n_events=ev, chunk=1)
    st = batch.exec_stats()
    # alock bucket: 6 rows -> units [4, 2] -> 2 dispatches; mcs: 4 rows
    # -> [4] -> 1; spinlock: 2 rows -> [2] -> 1
    assert st["dispatches"] == 4
    # each bucket runner compiles once per distinct row count it saw:
    # alock {4, 2}, mcs {4}, spinlock {2} -> 4 executables
    assert st["compiles"] == 4
    for b, c in zip(base, ch):
        np.testing.assert_array_equal(b.lat_ns, c.lat_ns)
        np.testing.assert_array_equal(b.ops, c.ops)
        np.testing.assert_array_equal(b.per_thread_ops, c.per_thread_ops)
    batch.reset_exec_stats()
    batch.sweep(cfgs, n_seeds=2, n_events=ev, chunk=1)
    st2 = batch.exec_stats()
    assert st2["dispatches"] == 4 and st2["compiles"] == 0


def test_sweep_devices_path_matches_unsharded():
    """devices= routes through the shard_map runner (1-device mesh on CPU
    CI) and must not perturb results."""
    cfgs = [SimConfig("spinlock", 2, 2, 8, 0.9, seed=2)]
    base = batch.sweep(cfgs, n_seeds=2, n_events=EV)
    shd = batch.sweep(cfgs, n_seeds=2, n_events=EV, devices=jax.devices())
    np.testing.assert_array_equal(base[0].lat_ns, shd[0].lat_ns)
    np.testing.assert_array_equal(base[0].ops, shd[0].ops)


# ---------------------------------------------------------------------------
# satellites: Zipf workload + topology validation


def test_zipf_cdf_properties():
    u = zipf_cdf(8, 0.0)
    np.testing.assert_allclose(u, np.arange(1, 9) / 8.0, rtol=1e-6)
    z = zipf_cdf(8, 1.5)
    assert z.dtype == np.float32
    assert np.all(np.diff(z) > 0) and z[-1] == pytest.approx(1.0)
    # skew concentrates mass on the first ranks
    assert z[0] > u[0]
    with pytest.raises(ValueError):
        zipf_cdf(0, 1.0)


def test_zipf_skew_changes_contention():
    """zipf_s rides the traced axis: same shape bucket, different dynamics
    (heavier skew -> more contention on the hot lock)."""
    flat = SimConfig("alock", 2, 2, 8, 1.0, seed=0, zipf_s=0.0)
    hot = SimConfig("alock", 2, 2, 8, 1.0, seed=0, zipf_s=4.0)
    r0 = simulate(flat, n_events=6_000)
    r4 = simulate(hot, n_events=6_000)
    assert r0.ops > 0 and r4.ops > 0
    # with s=4 nearly every draw is the node's rank-0 lock; the serialized
    # hot lock completes fewer ops in the same event count
    assert r4.ops < r0.ops
    # and the two ride one executable (same shape key)
    assert batch.shape_key(flat, 6_000) == batch.shape_key(hot, 6_000)


def test_topology_rejects_uneven_lock_partition():
    with pytest.raises(ValueError, match=r"\(n_locks, n_nodes\)=\(7, 2\)"):
        topology("alock", 2, 2, 7)
    with pytest.raises(ValueError):
        simulate(SimConfig("alock", 3, 2, 8, 0.9), n_events=10)

"""Acceptance tests for the static jaxpr lint (``repro.analysis``).

Four gates, mirroring the CI lint leg:

  1. the shipped engine is lint-clean — every rule over a real traced
     entrypoint catalog yields zero findings;
  2. the known-bad corpus keeps every rule family alive (>= 4 distinct
     rule ids across all 4 families);
  3. the CLI contract holds in a real subprocess (``--strict`` exit 0 on
     this repo, ``--selftest`` exit 0, ``--imports`` names dead weight,
     unknown ``--rules`` exit 2);
  4. the pairs-path jaxpr matches its golden primitive-set snapshot
     (regenerate with ``REPRO_UPDATE_GOLDENS=1``).

Tracing is scoped to three scenarios — node-churn (the classic closed
loop) plus read-heavy and rack-locality (the alock-rw / hlock buckets
with their gated read-probability, coin-stream and rack operands) — to
keep runtime modest; the full catalog runs in CI's lint leg via
``--strict``.
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (
    RULES,
    bucket_signature,
    check_bucket_signatures,
    check_env_resolution,
    check_runner_cache_keys,
    run_rules,
    trace_entrypoints,
    walk_jaxpr,
)

ROOT = Path(__file__).resolve().parent.parent
GOLDEN = ROOT / "tests" / "golden" / "run_events_pairs_primitives.txt"
ENV = dict(os.environ, PYTHONPATH=str(ROOT / "src"), JAX_PLATFORMS="cpu")

# one scenario's worth of traced entrypoints, shared across tests
_EPS = None


def _eps():
    global _EPS
    if _EPS is None:
        _EPS = trace_entrypoints(
            scenarios=["node-churn", "read-heavy", "rack-locality"],
            n_events=512)
    return _EPS


# ---------------------------------------------------------------- gate 1

def test_clean_entrypoints_zero_findings():
    """The shipped engine must be lint-clean: all 8 rules, all 4 kinds
    (xla-batch, pallas-i64, pallas-native, pallas-pairs), 0 findings."""
    eps = _eps()
    kinds = {e.kind for e in eps}
    assert {"xla-batch", "pallas-i64",
            "pallas-native", "pallas-pairs"} <= kinds, kinds
    findings = run_rules(eps)
    assert findings == [], "\n".join(f.format() for f in findings)


def test_pairs_trace_has_no_wide_avals():
    """Belt-and-braces on X001's premise: the x64-off pairs traces really
    contain zero 64-bit avals — across every bucket, so the hlock rack
    operand and the alock-rw read-coin stream are covered too."""
    from repro.analysis import all_avals
    from repro.analysis.rules import _wide
    eps = [e for e in _eps() if e.kind == "pallas-pairs"]
    assert eps
    for ep in eps:
        wide = [(str(a), w) for a, w in all_avals(ep.jaxpr)
                if _wide(getattr(a, "dtype", None))]
        assert wide == [], (ep.name, wide[:10])


# ---------------------------------------------------------------- gate 2

def test_corpus_fires_all_families():
    from repro.analysis.fixtures import run_corpus
    per_family = run_corpus()
    assert sorted(per_family) == ["mosaic-lowerability", "retrace-hazards",
                                  "vmem-consistency", "x64-cleanliness"]
    blind = [fam for fam, fs in per_family.items() if not fs]
    assert not blind, f"rule families gone blind: {blind}"
    fired = {f.rule for fs in per_family.values() for f in fs}
    assert len(fired) >= 4, fired
    assert {RULES[r].family for r in fired} == set(per_family), fired


def test_rack_offender_fires_m001():
    """The topology counterfactual: an int64 rack index inside the tier
    compare must trip the Mosaic-lowerability family (a widened rack
    operand can never reach the shipped kernel unnoticed)."""
    from repro.analysis.fixtures import rack_offender
    fs = run_rules([rack_offender()], rules=["M001"])
    assert fs, "M001 went blind on the 64-bit rack-index fixture"
    assert all(f.rule == "M001" for f in fs), fs


def test_every_finding_is_stamped():
    """Corpus findings carry their rule id, family, severity, entrypoint
    and a non-empty message — the structured contract ``--json`` relies
    on."""
    from repro.analysis.fixtures import run_corpus
    for fs in run_corpus().values():
        for f in fs:
            assert f.rule in RULES, f
            assert f.family == RULES[f.rule].family, f
            assert f.severity in ("error", "warning"), f
            assert f.entrypoint and f.message, f


def test_lazy_env_resolution_is_caught():
    """R002 positive: a resolver that ignores REPRO_EVENT_CLOCKS fires;
    the real resolver (eager read at call time) stays clean."""
    from repro.analysis.fixtures import lazy_resolver
    assert check_env_resolution(lazy_resolver), \
        "R002 went blind on the lazy-resolver fixture"
    assert check_env_resolution() == []
    assert check_runner_cache_keys() == []


def test_bucket_signature_drift_is_caught():
    """R003 positive/negative: a dtype-drifted replica in a bucket fires;
    the real sweep buckets stay one-signature-per-bucket."""
    from repro.analysis.fixtures import bucket_offender
    assert check_bucket_signatures(lowered_by_bucket=bucket_offender())
    assert check_bucket_signatures(
        n_events=512, scenarios=["node-churn", "hot-key-storm"]) == []


def test_bucket_signature_is_shape_and_dtype():
    from repro.workloads import Workload, lower
    ops = lower(Workload("alock", 2, 2, 8, locality=0.9), 256).operands
    sig = bucket_signature(ops)
    assert sig and all(len(t) == 3 for t in sig), sig
    import numpy as np
    drifted = ops._replace(locality=np.asarray(ops.locality, np.float64))
    assert bucket_signature(drifted) != sig


# ---------------------------------------------------------------- gate 3

def _cli(*args, timeout=560):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, env=ENV, timeout=timeout)


def test_cli_strict_is_clean_on_this_repo():
    # read-heavy / rack-locality put the alock-rw and hlock buckets (rack
    # operand, read-coin stream) under the same strict gate
    r = _cli("--strict", "--scenarios",
             "node-churn,read-heavy,rack-locality", "--events", "512")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "lint-clean." in r.stdout, r.stdout


def test_cli_selftest_passes():
    r = _cli("--selftest")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "selftest passed." in r.stdout, r.stdout
    assert "BLIND" not in r.stdout, r.stdout


def test_cli_imports_gate_clean_with_quarantine():
    r = _cli("--imports")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "unreachable" in r.stdout
    # the superseded tick kernel is real dead weight from the simulator's
    # roots, parked under an explicit quarantine entry rather than deleted
    assert "repro.kernels.alock_tick.kernel" in r.stdout, r.stdout
    assert "quarantined" in r.stdout, r.stdout
    assert "0 unexpected" in r.stdout, r.stdout
    assert "imports gate: clean." in r.stdout, r.stdout


def test_imports_gate_flags_unexpected_and_stale():
    """The gate is actionable both ways: an unreachable module without a
    quarantine entry fails, and a quarantine entry whose tree became
    reachable (or vanished) fails too."""
    from repro.analysis import imports as imp
    quarantined, unexpected, stale = imp.classify()
    assert quarantined and not unexpected and not stale
    # drop one entry -> its modules become unexpected
    trimmed = {k: v for k, v in imp.QUARANTINED.items()
               if k != "repro.kernels.alock_tick"}
    orig = imp.QUARANTINED
    try:
        imp.QUARANTINED = trimmed
        _, unexpected, _ = imp.classify()
        assert "repro.kernels.alock_tick.kernel" in unexpected
        text, rc = imp.report()
        assert rc == 1 and "UNEXPECTED" in text
        # add a prefix covering nothing -> stale
        imp.QUARANTINED = {**orig, "repro.no_such_pkg": "ghost"}
        _, _, stale = imp.classify()
        assert stale == ["repro.no_such_pkg"]
        text, rc = imp.report()
        assert rc == 1 and "STALE" in text
    finally:
        imp.QUARANTINED = orig


def test_cli_unknown_rule_id_exits_2():
    r = _cli("--rules", "M999")
    assert r.returncode == 2, r.stdout + r.stderr
    assert "unknown rule ids" in r.stderr, r.stderr


# ---------------------------------------------------------------- gate 4

def _pairs_primitives():
    # union over every pairs bucket: alock/spinlock/mcs plus the hlock and
    # alock-rw op classes all contribute to the pinned set
    prims = set()
    for ep in _eps():
        if ep.kind == "pallas-pairs":
            prims |= {s.eqn.primitive.name for s in walk_jaxpr(ep.jaxpr)}
    return sorted(prims)


def test_pairs_golden_primitive_set():
    """The run_events_pairs trace's primitive set is pinned: a *new*
    primitive appearing on the hot path (e.g. ``scan`` returning after
    the i32-counter while_loop fix, or a stray ``convert_element_type``
    widening) fails; primitives a newer jax version stops emitting are
    tolerated (the golden is a ceiling, not an exact pin, so CI's
    latest-jax leg stays green on lowering simplifications).

    Regenerate after an intentional kernel change with
    ``REPRO_UPDATE_GOLDENS=1 PYTHONPATH=src python -m pytest
    tests/test_analysis.py -k golden``.
    """
    got = _pairs_primitives()
    if os.environ.get("REPRO_UPDATE_GOLDENS"):
        GOLDEN.write_text("\n".join(got) + "\n")
        pytest.skip(f"golden regenerated: {GOLDEN}")
    assert GOLDEN.exists(), f"missing golden {GOLDEN}"
    want = GOLDEN.read_text().split()
    added = sorted(set(got) - set(want))
    assert not added, (
        f"new primitives entered the run_events_pairs trace: {added} — "
        f"intentional? regenerate with REPRO_UPDATE_GOLDENS=1")
    # the loop fix is load-bearing: scan must never return to this path
    assert "scan" not in got and "while" in got, got


def test_golden_file_is_sorted_unique():
    names = GOLDEN.read_text().split()
    assert names == sorted(set(names)), "golden file must be sorted/unique"

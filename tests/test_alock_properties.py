"""The paper's TLA+ properties, checked exhaustively and adversarially."""
import itertools

import pytest
from hypothesis_compat import given, settings, st

from repro.core import machine as mc
from repro.core.tla import bounded_overtaking, explore

L, R = mc.LOCAL, mc.REMOTE


@pytest.mark.parametrize("machine", ["alock", "mcs", "spinlock"])
@pytest.mark.parametrize("cohorts", [(L, R), (L, L, R), (L, R, R)])
def test_model_check_small(machine, cohorts):
    r = explore(machine, cohorts, b_init=(2, 3))
    assert r.mutex_ok, r.violations[:2]
    assert r.deadlock_free, r.violations[:2]
    assert r.eventual_entry, r.violations[:2]


def test_model_check_alock_2plus2():
    r = explore("alock", (L, L, R, R), b_init=(2, 2))
    assert r.ok and r.states > 10_000


def test_model_check_alock_budget_variants():
    for b in [(1, 1), (1, 3), (3, 1)]:
        r = explore("alock", (L, L, R), b_init=b)
        assert r.ok, (b, r.violations[:2])


@given(st.lists(st.integers(0, 3), min_size=200, max_size=2000),
       st.sampled_from([(L, L, R, R), (L, R, R, R), (L, L, L, R)]),
       st.tuples(st.integers(1, 4), st.integers(1, 6)))
def test_mutex_random_schedules(sched, cohorts, b_init):
    """Hypothesis adversarial schedules: never two threads in CS."""
    st_ = mc.initial_state(4)
    for tid in sched:
        st_, _ = mc.alock_step(st_, tid, cohorts[tid], b_init)
        assert sum(1 for t in range(4) if st_.pc[t] == mc.CS) <= 1


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=15)
def test_alock_bounded_overtaking(seed):
    """Budgets make overtaking bounded (fairness). A waiting thread sees at
    most ~(b_local + b_remote) CS entries before entering."""
    import random
    rng = random.Random(seed)
    cohorts = (L, L, R, R)
    b = (2, 3)
    sched = (rng.randrange(4) for _ in itertools.count())
    worst = bounded_overtaking("alock", cohorts, b, sched, steps=30_000)
    assert worst <= b[0] + b[1] + 4, worst


def test_quiescence_resets_tails():
    """If everyone returns to NCS, both Peterson flags (tails) are clear."""
    import random
    rng = random.Random(3)
    cohorts = (L, R, R)
    st_ = mc.initial_state(3)
    for _ in range(50_000):
        tid = rng.randrange(3)
        st_, _ = mc.alock_step(st_, tid, cohorts[tid], (2, 2))
    # drive everyone to NCS round-robin (each gets unlimited turns)
    for tid in range(3):
        guard = 0
        while st_.pc[tid] != mc.NCS:
            prev = st_
            st_, _ = mc.alock_step(st_, tid, cohorts[tid], (2, 2))
            guard += 1
            if st_ == prev:
                # blocked on another thread: give others one step each
                for o in range(3):
                    if o != tid and st_.pc[o] != mc.NCS:
                        st_, _ = mc.alock_step(st_, o, cohorts[o], (2, 2))
            assert guard < 10_000
    assert st_.tail == (0, 0)

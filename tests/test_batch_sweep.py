"""batch.sweep contract: per-seed bitwise equality with simulate(), one
compile per shape bucket, aggregates consistent with the samples."""
import numpy as np
import pytest
import jax

from repro.core import batch
from repro.core.sim import SimConfig, simulate

EV = 4_000


def test_sweep_bitwise_matches_simulate():
    """Every (config, seed) replica out of the vmapped engine equals the
    serial simulate() run bit for bit."""
    cfgs = [SimConfig("alock", 2, 2, 8, 0.9, (2, 3), seed=7),
            SimConfig("spinlock", 2, 2, 8, 0.5, (5, 20), seed=1),
            SimConfig("mcs", 3, 2, 6, 0.95, (5, 20), seed=3)]
    res = batch.sweep(cfgs, n_seeds=2, n_events=EV)
    for cfg, br in zip(cfgs, res):
        assert br.config == cfg and br.n_seeds == 2
        np.testing.assert_array_equal(br.seeds, cfg.seed + np.arange(2))
        for j, seed in enumerate(br.seeds):
            r = simulate(cfg._replace(seed=int(seed)), n_events=EV)
            assert int(br.ops[j]) == r.ops
            assert int(br.sim_ns[j]) == r.sim_ns
            assert float(br.throughput_mops[j]) == r.throughput_mops
            np.testing.assert_array_equal(br.lat_ns[j],
                                          np.asarray(r.lat_ns))
            np.testing.assert_array_equal(br.per_thread_ops[j],
                                          np.asarray(r.per_thread_ops))
            assert int(br.reacquires[j]) == r.reacquires
            assert int(br.passes[j]) == r.passes
            assert br.result(j).ops == r.ops


def test_sweep_compiles_once_per_shape_bucket():
    """Configs differing only in locality/budget/seed share one executable;
    a second sweep over the same buckets reuses the cache."""
    jax.clear_caches()
    cfgs = ([SimConfig("alock", 2, 2, 8, loc, (2, 3)) for loc in
             (0.5, 0.9, 1.0)]
            + [SimConfig("alock", 2, 2, 8, 0.9, (1, 1), seed=5)]
            + [SimConfig("mcs", 2, 2, 8, 0.9)])
    batch.sweep(cfgs, n_seeds=2, n_events=2_000)
    n_keys = len({batch.shape_key(c, 2_000) for c in cfgs})
    assert n_keys == 2
    assert batch._run_events_batch._cache_size() == n_keys
    batch.sweep(cfgs, n_seeds=2, n_events=2_000)
    assert batch._run_events_batch._cache_size() == n_keys


def test_sweep_clocks_are_int64():
    """Satellite of the int32-wrap fix: latencies come back as real int64
    (enable_x64 held during tracing), so ~hours of simulated time cannot
    wrap negative."""
    br = batch.sweep([SimConfig("alock", 2, 2, 8, 0.9)], n_seeds=1,
                     n_events=EV)[0]
    assert br.lat_ns.dtype == np.int64
    assert br.sim_ns.dtype == np.int64
    valid = br.lat_ns[br.lat_ns >= 0]
    assert (valid > 0).all()


def test_aggregates_consistent_with_samples():
    br = batch.sweep([SimConfig("alock", 2, 2, 8, 0.9)], n_seeds=3,
                     n_events=EV)[0]
    s = br.throughput_mops
    assert br.mean_mops == pytest.approx(float(s.mean()))
    assert br.ci95_mops == pytest.approx(
        1.96 * float(s.std(ddof=1)) / np.sqrt(3))
    pool = br.lat_ns[br.lat_ns >= 0]
    assert br.p50_lat_ns == pytest.approx(np.percentile(pool, 50))
    assert br.p99_lat_ns == pytest.approx(np.percentile(pool, 99))
    assert br.mean_lat_us == pytest.approx(float(pool.mean()) / 1e3)
    m, ci = br.lat_pct(50)
    per_seed = [np.percentile(row[row >= 0], 50) for row in br.lat_ns]
    assert m == pytest.approx(np.mean(per_seed))
    assert ci >= 0.0
    # seeds are independent replicas, not copies
    assert len({int(o) for o in br.ops}) > 1 or len(
        {int(t) for t in br.sim_ns}) > 1


def test_single_seed_ci_is_zero():
    br = batch.sweep([SimConfig("mcs", 2, 2, 8, 0.9)], n_seeds=1,
                     n_events=EV)[0]
    assert br.ci95_mops == 0.0
    assert br.lat_pct(99)[1] == 0.0

"""Differential harness for the native-TPU clock representation.

Runs the event-loop kernel with the hi/lo i32-pair representation forced
on (interpret mode, **no** ``enable_x64`` anywhere near the kernel — the
x64-off CI leg executes this file with ``JAX_ENABLE_X64=0`` to emulate the
TPU i32-vector constraint) and asserts bitwise equality with the XLA
engine:

  * an alg x phased x zipf x churn operand matrix covering **all five
    algorithms** (alock, spinlock, mcs, the hierarchical hlock with a
    non-trivial rack topology, and the reader-writer alock-rw with
    non-uniform read mixes) with mid-chunk phase boundaries;
  * **every simulator scenario in the registry** (uniform-grid,
    hot-key-storm, mixed-locality, node-churn, paper-fig5, congested-nic,
    budget-ramp, limping-node, fail-slow-cascade, read-heavy,
    rack-locality, plus the open-loop open-loop-ramp and burst-storm,
    whose buckets carry R request slots and four extra per-request
    outputs) via ``repro.experiments.scenario_workloads``;
  * latency-ring overflow (``latn`` wrapping past ``lat_samples``) across
    all three engines: XLA, i64-pallas, i32-pair-pallas.

The XLA oracle still runs under a local ``enable_x64()`` (its clocks are
real int64); pair outputs are packed host-side with ``i32pair.pack_np`` so
the comparison itself never needs x64.
"""
import numpy as np
import pytest
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core.sim import topology, zipf_cdf
from repro.experiments import scenario_names, scenario_workloads
from repro.kernels.event_loop import i32pair as p32
from repro.kernels.event_loop.ops import (resolve_representation,
                                          run_events, run_events_pairs)
from repro.kernels.event_loop.ref import run_events_ref
from repro.workloads import (Phase, Workload, WorkloadOperands, lower,
                             pad_phases)

EV = 1100


#: engine output order; the last four rows only exist on open-loop (R > 0)
#: buckets — arr/wq/soj are clock-typed ((hi, lo) pairs on the pairs path)
#: and rstat is plain i32
OUT_NAMES = ("done", "lat", "lat_n", "t_end", "nreacq", "npass",
             "arr", "wq", "soj", "rstat")


def _pk(p):
    """(hi, lo) i32 pair -> np int64."""
    return p32.pack_np(np.asarray(p[0]), np.asarray(p[1]))


def _pack_outputs(out):
    """(done, (lat_hi, lat_lo), lat_n, (te_hi, te_lo), ...) -> np int64.

    Handles both the 6-output closed loop and the 10-output open loop
    (arr/wq/soj pairs packed, rstat passed through).
    """
    done, lat_p, lat_n, te_p, nreacq, npass, *extra = out
    base = (np.asarray(done), _pk(lat_p), np.asarray(lat_n), _pk(te_p),
            np.asarray(nreacq), np.asarray(npass))
    if extra:
        arr_p, wq_p, soj_p, rstat = extra
        base += (_pk(arr_p), _pk(wq_p), _pk(soj_p), np.asarray(rstat))
    return base


def _assert_bitwise(ref, got):
    assert len(ref) == len(got), (len(ref), len(got))
    for name, a, b in zip(OUT_NAMES, ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"mismatch in {name}")


def _stack_operands(workloads, n_events, **lower_kw):
    """Lower specs, pad phases to the bucket max, stack a replica axis —
    the same layout ``batch.sweep`` builds for one shape bucket."""
    lowered = [lower(w, n_events, **lower_kw) for w in workloads]
    keys = {lw.shape_key for lw in lowered}
    assert len(keys) == 1, f"one bucket at a time, got {keys}"
    pmax = max(lw.operands.n_phases for lw in lowered)
    ops = [pad_phases(lw.operands, pmax) for lw in lowered]
    leaves = [np.stack([np.asarray(getattr(o, f)) for o in ops])
              for f in WorkloadOperands._fields]
    return lowered[0], WorkloadOperands(*(jnp.asarray(a) for a in leaves))


@pytest.mark.parametrize("alg", ["alock", "spinlock", "mcs", "hlock",
                                 "alock-rw"])
def test_native_repr_bitwise_phased_zipf_churn(alg):
    """The tentpole contract on handcrafted operands, for all five
    algorithms: per-thread locality, per-phase Zipf CDFs + cost rows +
    budgets, a downed node, and phase edges that land mid event-chunk —
    i32-pair kernel (x64 off) vs the int64 XLA loop, bitwise. hlock gets
    a non-trivial two-rack topology and alock-rw a *non-uniform*
    per-phase per-thread read_frac, so the new operands flip across the
    mid-chunk phase boundary too."""
    N, tpn, K = 3, 4, 6
    T, B, P = N * tpn, 5, 2
    tn, ln, costs = topology(alg, N, tpn, K)
    rng = np.random.default_rng(0)
    loc = rng.uniform(0.3, 1.0, (B, P, T)).astype(np.float32)
    zc = np.stack([[zipf_cdf(K // N, s) for s in row]
                   for row in rng.uniform(0.0, 2.0, (B, P))])
    active = np.ones((B, P, T), np.int32)
    active[:, 1, :tpn] = 0          # node 0 down in the second phase
    cst = np.tile(np.int32(costs), (B, P, 1))
    cst[:, 1, 4:6] *= 2
    # fail-slow: node 1 limps at 3x in the first phase only, so the
    # degradation operand flips across the mid-chunk phase edge too
    nm = np.ones((B, P, N), np.float32)
    nm[:, 0, 1] = 3.0
    # hlock: nodes 0+1 share a rack, node 2 is alone (non-trivial tiers);
    # others get the trivial every-node-its-own-rack topology
    rack = (np.int32([0, 0, 1]) if alg == "hlock"
            else np.arange(N, dtype=np.int32))
    # alock-rw: read-light first phase, read-heavy second, jittered per
    # thread; inert zeros for every other algorithm
    if alg == "alock-rw":
        rf = np.concatenate([rng.uniform(0.1, 0.3, (1, T)),
                             rng.uniform(0.8, 1.0, (1, T))]
                            ).astype(np.float32)
        rf = np.tile(rf, (B, 1, 1))
    else:
        rf = np.zeros((B, P, T), np.float32)
    wl = WorkloadOperands(
        locality=jnp.asarray(loc), zcdf=jnp.asarray(np.float32(zc)),
        edges=jnp.asarray(np.tile(np.int32([0, 600]), (B, 1))),
        think_ns=jnp.asarray(np.tile(np.int32([500, 250]), (B, 1))),
        active=jnp.asarray(active),
        b_init=jnp.asarray(np.tile(np.int32([[2, 3], [1, 5]]), (B, 1, 1))),
        seed=jnp.arange(B, dtype=jnp.int32) + 11,
        cost_rows=jnp.asarray(cst), node_mult=jnp.asarray(nm),
        # closed-loop placeholders: R == 0 arrival rows
        arr_gap_ns=jnp.zeros((B, P), jnp.float32),
        arr_edges=jnp.zeros((B, P), jnp.int32),
        arr_qcap=jnp.full((B, P), np.iinfo(np.int32).max, jnp.int32),
        arr_token=jnp.zeros((B, P, 2), jnp.float32),
        arr_fix=jnp.zeros((B, 0), jnp.int32),
        rack=jnp.asarray(np.tile(rack, (B, 1))),
        read_frac=jnp.asarray(rf))
    with enable_x64():
        ref = [np.asarray(r) for r in
               run_events_ref(alg, T, N, K, EV, wl, tn, ln)]
    # the phase edge at 600 falls mid-chunk (600 % 256 != 0)
    out = run_events_pairs(alg, T, N, K, EV, wl, tn, ln,
                           tile=2, ev_chunk=256, interpret=True)
    _assert_bitwise(ref, _pack_outputs(out))


def test_node_mult_phase_edge_mid_chunk_bitwise():
    """Fail-slow satellite: a phase program whose *only* difference across
    the boundary is ``node_mult`` (node 0 healthy -> 4x limp), with the
    edge landing mid event-chunk (605 % 256 != 0) — i32-pair kernel (x64
    off) vs the int64 XLA loop, bitwise, through the full spec -> lower ->
    pad path."""
    w = Workload("alock", n_nodes=4, threads_per_node=3, n_locks=8,
                 locality=0.8, seed=9,
                 phases=(Phase(frac=0.55),
                         Phase(frac=0.45, node_mult="limp-node0-4x")))
    lw = lower(w, EV)
    alg, T, N, K, _, _ = lw.shape_key
    tn, ln, _ = topology(alg, N, T // N, K)
    wl = WorkloadOperands(*(jnp.asarray(a)[None] for a in lw.operands))
    with enable_x64():
        ref = [np.asarray(r) for r in
               run_events_ref(alg, T, N, K, EV, wl, tn, ln)]
    out = run_events_pairs(alg, T, N, K, EV, wl, tn, ln,
                           tile=1, ev_chunk=256, interpret=True)
    _assert_bitwise(ref, _pack_outputs(out))
    # the limp is observable: the degraded half really runs slower than a
    # healthy clone of the same spec (sanity, not bitwise)
    healthy = lower(w.replace(phases=(Phase(frac=0.55), Phase(frac=0.45))),
                    EV)
    wl_h = WorkloadOperands(*(jnp.asarray(a)[None] for a in healthy.operands))
    with enable_x64():
        ref_h = [np.asarray(r) for r in
                 run_events_ref(alg, T, N, K, EV, wl_h, tn, ln)]
    assert ref[3][0] > ref_h[3][0]      # t_end grows under the limp


def test_read_frac_phase_edge_mid_chunk_bitwise():
    """Reader-writer satellite: an alock-rw phase program whose read mix
    flips from a scalar read-light phase to a *per-thread* read-heavy
    tuple, with the edge landing mid event-chunk (605 % 256 != 0) —
    i32-pair kernel (x64 off) vs the int64 XLA loop, bitwise, through the
    full spec -> lower -> pad path."""
    T = 12
    heavy = tuple(0.7 + 0.02 * t for t in range(T))   # non-uniform row
    w = Workload("alock-rw", n_nodes=4, threads_per_node=3, n_locks=8,
                 locality=0.8, seed=9,
                 phases=(Phase(frac=0.55, read_frac=0.15),
                         Phase(frac=0.45, read_frac=heavy)))
    lw = lower(w, EV)
    alg, T, N, K, _, _ = lw.shape_key
    tn, ln, _ = topology(alg, N, T // N, K)
    wl = WorkloadOperands(*(jnp.asarray(a)[None] for a in lw.operands))
    # the lowered operand really is non-uniform across the phase edge
    rf = np.asarray(lw.operands.read_frac)
    assert rf.shape == (2, T)
    assert np.all(rf[0] == np.float32(0.15)) and len(set(rf[1])) == T
    with enable_x64():
        ref = [np.asarray(r) for r in
               run_events_ref(alg, T, N, K, EV, wl, tn, ln)]
    out = run_events_pairs(alg, T, N, K, EV, wl, tn, ln,
                           tile=1, ev_chunk=256, interpret=True)
    _assert_bitwise(ref, _pack_outputs(out))
    # the mix is observable: a near-read-only clone of the same spec
    # completes ops at a higher simulated rate than a writer-only clone
    # (readers share the CS; sanity, not bitwise)
    rates = {}
    for tag, mix in (("rd", 0.99), ("wr", 0.0)):
        lc = lower(w.replace(phases=(Phase(frac=0.55, read_frac=mix),
                                     Phase(frac=0.45, read_frac=mix))), EV)
        wl_c = WorkloadOperands(*(jnp.asarray(a)[None] for a in lc.operands))
        with enable_x64():
            ref_c = [np.asarray(r) for r in
                     run_events_ref(alg, T, N, K, EV, wl_c, tn, ln)]
        rates[tag] = ref_c[0].sum() / float(ref_c[3][0])
    assert rates["rd"] > rates["wr"]


def test_registry_scenarios_bitwise_i32pair():
    """Acceptance gate: every simulator scenario in the registry is
    bitwise-identical through the i32-pair kernel. Workloads are grouped
    into shape buckets (one ref + one kernel compile per bucket) exactly
    like a production sweep; lat_samples is shrunk so the interpret-mode
    ring stays cheap (both engines get the same value)."""
    ev, lat_samples = 400, 512
    sim_scenarios = {}
    for name in scenario_names():
        ws = scenario_workloads(name)
        if ws is None:
            assert name == "coord-stress"   # only the threaded coord plane
            continue
        sim_scenarios[name] = ws
    assert set(sim_scenarios) == {
        "uniform-grid", "hot-key-storm", "mixed-locality", "node-churn",
        "paper-fig5", "congested-nic", "budget-ramp", "limping-node",
        "fail-slow-cascade", "open-loop-ramp", "burst-storm",
        "read-heavy", "rack-locality"}
    assert any(w.arrivals is not None
               for ws in sim_scenarios.values() for w in ws)
    # the registry really sweeps all five algorithms, including the
    # hierarchical lock (non-trivial topology) and the reader-writer
    # variant (non-zero read mixes)
    algs = {w.alg for ws in sim_scenarios.values() for w in ws}
    assert algs == {"alock", "spinlock", "mcs", "hlock", "alock-rw"}
    assert any(w.topology is not None
               for w in sim_scenarios["rack-locality"])
    assert any(w.alg == "alock-rw" and float(np.max(w.read_frac)) > 0
               for w in sim_scenarios["read-heavy"])

    buckets: dict[tuple, list] = {}
    for name, ws in sim_scenarios.items():
        for w in ws:
            buckets.setdefault(lower(w, ev).shape_key, []).append((name, w))

    for key, items in buckets.items():
        alg, T, N, K, _, R = key
        tn, ln, _ = topology(alg, N, T // N, K)
        _, wl = _stack_operands([w for _, w in items], ev)
        with enable_x64():
            ref = [np.asarray(r) for r in
                   run_events_ref(alg, T, N, K, ev, wl, tn, ln,
                                  lat_samples=lat_samples)]
        # ev_chunk=192: the scenarios' phase edges (ev * 0.3/0.34/0.4...)
        # all land mid-chunk
        out = run_events_pairs(alg, T, N, K, ev, wl, tn, ln, tile=3,
                               ev_chunk=192, interpret=True,
                               lat_samples=lat_samples)
        got = _pack_outputs(out)
        assert len(ref) == len(got) == (10 if R else 6), key
        for i, (name, w) in enumerate(items):
            for fname, a, b in zip(OUT_NAMES, ref, got):
                np.testing.assert_array_equal(
                    a[i], b[i],
                    err_msg=f"scenario {name} workload {i} ({w.alg}): "
                            f"{fname} diverged")


def test_ring_overflow_identical_across_engines():
    """latn wrapping past lat_samples: ring contents and p50/p99
    aggregates must match on XLA, i64-pallas and i32-pair-pallas."""
    alg, N, tpn, K, lat_samples = "alock", 2, 2, 8, 64
    T = N * tpn
    ev = 2500                       # ~400 completions >> 64 slots
    tn, ln, _ = topology(alg, N, tpn, K)
    w = lower(Workload(alg, N, tpn, K, locality=0.9, seed=3), ev)
    wl = WorkloadOperands(*(jnp.asarray(a)[None] for a in w.operands))
    with enable_x64():
        ref = [np.asarray(r) for r in
               run_events_ref(alg, T, N, K, ev, wl, tn, ln,
                              lat_samples=lat_samples)]
        i64 = run_events(alg, T, N, K, ev, wl, tn, ln, interpret=True,
                         representation="i64", lat_samples=lat_samples,
                         ev_chunk=512)
        i64 = [np.asarray(r) for r in i64]
    pair = _pack_outputs(run_events_pairs(
        alg, T, N, K, ev, wl, tn, ln, interpret=True,
        lat_samples=lat_samples, ev_chunk=512))

    assert ref[2][0] > 2 * lat_samples      # the ring really wrapped
    assert (ref[1] >= 0).all()              # ... and every slot was filled
    _assert_bitwise(ref, i64)
    _assert_bitwise(ref, pair)
    for eng in (i64, pair):
        assert np.percentile(eng[1][0], 50) == np.percentile(ref[1][0], 50)
        assert np.percentile(eng[1][0], 99) == np.percentile(ref[1][0], 99)


def test_packed_run_events_i32pair_matches_i64():
    """The public ``run_events(representation=)`` contract: both
    representations return identical int64 outputs under x64."""
    alg, N, tpn, K, ev = "mcs", 2, 2, 8, 900
    T = N * tpn
    tn, ln, _ = topology(alg, N, tpn, K)
    w = lower(Workload(alg, N, tpn, K, locality=0.85, seed=5), ev)
    wl = WorkloadOperands(*(jnp.asarray(a)[None] for a in w.operands))
    with enable_x64():
        a = run_events(alg, T, N, K, ev, wl, tn, ln, interpret=True,
                       representation="i64")
        b = run_events(alg, T, N, K, ev, wl, tn, ln, interpret=True,
                       representation="i32pair")
        for x, y in zip(a, b):
            assert x.dtype == y.dtype
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_resolve_representation():
    assert resolve_representation("i64", interpret=True) == "i64"
    assert resolve_representation("i32pair", interpret=True) == "i32pair"
    assert resolve_representation("auto", interpret=True) == "i64"
    assert resolve_representation("auto", interpret=False) == "i32pair"
    with pytest.raises(ValueError, match="representation"):
        resolve_representation("i48", interpret=True)


def test_env_override_keys_the_jit_cache(monkeypatch):
    """Flipping REPRO_EVENT_CLOCKS mid-process must re-trace, not reuse a
    cached executable of the other representation — run_events_jit
    resolves the env *before* the jit boundary so it keys the cache. A
    fresh trace is observable through the VMEM plan it records (a cache
    hit records nothing), and both traces stay bitwise-equal."""
    from repro.kernels.event_loop import vmem
    from repro.kernels.event_loop.ops import run_events_jit
    alg, N, tpn, K, ev = "alock", 2, 2, 8, 600
    T = N * tpn
    tn, ln, _ = topology(alg, N, tpn, K)
    w = lower(Workload(alg, N, tpn, K, locality=0.9, seed=2), ev)
    wl = WorkloadOperands(*(jnp.asarray(a)[None] for a in w.operands))
    with enable_x64():
        a = run_events_jit(alg, T, N, K, ev, wl, tn, ln, interpret=True,
                           lat_samples=256)
        vmem.clear_plan()
        monkeypatch.setenv("REPRO_EVENT_CLOCKS", "i32pair")
        b = run_events_jit(alg, T, N, K, ev, wl, tn, ln, interpret=True,
                           lat_samples=256)
    plan = vmem.last_plan()
    assert plan is not None and plan.representation == "i32pair"
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_resolve_representation_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_EVENT_CLOCKS", "i32pair")
    assert resolve_representation("auto", interpret=True) == "i32pair"
    assert resolve_representation("i64", interpret=True) == "i64"
    monkeypatch.setenv("REPRO_EVENT_CLOCKS", "bogus")
    with pytest.raises(ValueError, match="REPRO_EVENT_CLOCKS"):
        resolve_representation("auto", interpret=True)

"""Threaded lock table (real concurrency) + coordination plane."""
import random
import threading
import time

from repro.coord.service import CoordService, LeaseManager, Membership
from repro.core.lock_table import LockTable


def test_threaded_mutual_exclusion_counter():
    table = LockTable(n_nodes=4, locks_per_node=4)
    counter = {"v": 0}
    N_OPS, THREADS = 200, 8
    violations = []
    holders = {"n": 0}

    def worker(node):
        rng = random.Random(node)
        for _ in range(N_OPS):
            lk = rng.randrange(16)
            d = table.lock(node, lk)
            if lk == 3:
                holders["n"] += 1
                if holders["n"] != 1:
                    violations.append(1)
                v = counter["v"]
                time.sleep(0)
                counter["v"] = v + 1
                holders["n"] -= 1
            table.unlock(d)

    ths = [threading.Thread(target=worker, args=(i % 4,))
           for i in range(THREADS)]
    [t.start() for t in ths]
    [t.join() for t in ths]
    assert not violations
    assert table.stats.ops == N_OPS * THREADS
    expected = sum(1 for i in range(THREADS)
                   for _ in [None]
                   if True) and counter["v"] > 0
    assert expected


def test_threaded_local_ops_stay_local():
    """100% locality => zero remote ops (the paper's headline property)."""
    table = LockTable(n_nodes=2, locks_per_node=4)

    def worker(node):
        for _ in range(100):
            lk = node * 4 + random.Random(node).randrange(4)
            d = table.lock(node, lk)
            table.unlock(d)

    ths = [threading.Thread(target=worker, args=(n,)) for n in range(2)]
    [t.start() for t in ths]
    [t.join() for t in ths]
    assert table.stats.remote_ops == 0
    assert table.stats.local_ops > 0


def test_lease_exclusive_and_expiry():
    svc = CoordService(4)
    lm = LeaseManager(svc, ttl_s=0.25)
    l0 = lm.acquire(0, "ckpt:100")
    assert l0 is not None
    assert lm.acquire(1, "ckpt:100") is None      # exclusive
    assert lm.renew(l0)
    time.sleep(0.3)
    l1 = lm.acquire(1, "ckpt:100")                # expiry steal
    assert l1 is not None and l1.epoch == l0.epoch + 1
    assert not lm.renew(l0)                       # old epoch fenced off


def test_lease_single_writer_under_contention():
    svc = CoordService(4)
    lm = LeaseManager(svc, ttl_s=5.0)
    wins = []

    def contender(n):
        lease = lm.acquire(n, "ckpt:7")
        if lease is not None:
            wins.append(n)

    ths = [threading.Thread(target=contender, args=(n,)) for n in range(8)]
    [t.start() for t in ths]
    [t.join() for t in ths]
    assert len(wins) == 1


def test_membership_and_straggler_steal():
    svc = CoordService(4)
    mem = Membership(svc, heartbeat_ttl=0.5)
    for n in range(3):
        mem.join(n)
    assert mem.alive() == [0, 1, 2]
    owned0 = mem.assign_shards(0, 9)
    assert len(owned0) == 3
    stolen = mem.steal_from(2, dead_node=0)
    assert set(owned0) <= set(stolen)
    time.sleep(0.6)
    mem.heartbeat(1)
    assert mem.alive() == [1]

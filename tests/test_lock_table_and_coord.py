"""Threaded lock table (real concurrency) + coordination plane."""
import random
import threading
import time

from repro.coord.service import CoordService, LeaseManager, Membership
from repro.coord.stress import ManualClock
from repro.core.lock_table import LockTable


def test_threaded_mutual_exclusion_counter():
    table = LockTable(n_nodes=4, locks_per_node=4)
    counter = {"v": 0}
    N_OPS, THREADS = 200, 8
    violations = []
    holders = {"n": 0}

    def worker(node):
        rng = random.Random(node)
        for _ in range(N_OPS):
            lk = rng.randrange(16)
            d = table.lock(node, lk)
            if lk == 3:
                holders["n"] += 1
                if holders["n"] != 1:
                    violations.append(1)
                v = counter["v"]
                time.sleep(0)
                counter["v"] = v + 1
                holders["n"] -= 1
            table.unlock(d)

    ths = [threading.Thread(target=worker, args=(i % 4,))
           for i in range(THREADS)]
    [t.start() for t in ths]
    [t.join() for t in ths]
    assert not violations
    assert table.stats.ops == N_OPS * THREADS
    expected = sum(1 for i in range(THREADS)
                   for _ in [None]
                   if True) and counter["v"] > 0
    assert expected


def test_threaded_local_ops_stay_local():
    """100% locality => zero remote ops (the paper's headline property)."""
    table = LockTable(n_nodes=2, locks_per_node=4)

    def worker(node):
        for _ in range(100):
            lk = node * 4 + random.Random(node).randrange(4)
            d = table.lock(node, lk)
            table.unlock(d)

    ths = [threading.Thread(target=worker, args=(n,)) for n in range(2)]
    [t.start() for t in ths]
    [t.join() for t in ths]
    assert table.stats.remote_ops == 0
    assert table.stats.local_ops > 0


def test_lease_exclusive_and_expiry():
    svc = CoordService(4)
    lm = LeaseManager(svc, ttl_s=0.25)
    l0 = lm.acquire(0, "ckpt:100")
    assert l0 is not None
    assert lm.acquire(1, "ckpt:100") is None      # exclusive
    assert lm.renew(l0)
    time.sleep(0.3)
    l1 = lm.acquire(1, "ckpt:100")                # expiry steal
    assert l1 is not None and l1.epoch == l0.epoch + 1
    assert not lm.renew(l0)                       # old epoch fenced off


def test_lease_single_writer_under_contention():
    svc = CoordService(4)
    lm = LeaseManager(svc, ttl_s=5.0)
    wins = []

    def contender(n):
        lease = lm.acquire(n, "ckpt:7")
        if lease is not None:
            wins.append(n)

    ths = [threading.Thread(target=contender, args=(n,)) for n in range(8)]
    [t.start() for t in ths]
    [t.join() for t in ths]
    assert len(wins) == 1


def test_lease_acquire_retry_rides_out_expiry():
    """attempts>1 + ManualClock: the exponential backoff sleeps advance
    virtual time past the holder's TTL, so a contender that would have
    given up in one shot wins a later attempt — no real sleeping."""
    svc = CoordService(4)
    clock = ManualClock()
    lm = LeaseManager(svc, ttl_s=0.5, clock=clock)
    l0 = lm.acquire(0, "ckpt:42")
    assert l0 is not None
    # one shot still fails fast (default attempts=1, clock untouched)
    assert lm.acquire(1, "ckpt:42") is None and clock.t == 0.0
    # backoff schedule 0.2, 0.4 pushes t to 0.6 > ttl: attempt 3 steals
    l1 = lm.acquire(1, "ckpt:42", attempts=3, backoff_base_s=0.2)
    assert l1 is not None and l1.epoch == l0.epoch + 1
    assert clock.t == 0.2 + 0.4


def test_lease_acquire_retry_deadline_and_jitter_deterministic():
    svc = CoordService(4)
    clock = ManualClock()
    lm = LeaseManager(svc, ttl_s=10.0, clock=clock)
    assert lm.acquire(0, "log") is not None
    # the deadline caps total backoff: no sleep overshoots it and the
    # loop stops retrying once it is spent
    assert lm.acquire(1, "log", attempts=50, backoff_base_s=0.2,
                      deadline_s=1.0) is None
    assert clock.t <= 1.0
    # a seeded rng jitters each sleep into [0.5, 1.0) of its nominal
    # value — deterministically, so two identical schedules agree
    t0 = clock.t
    lm.acquire(1, "log", attempts=4, backoff_base_s=0.2,
               rng=random.Random(7))
    d1 = clock.t - t0
    t0 = clock.t
    lm.acquire(1, "log", attempts=4, backoff_base_s=0.2,
               rng=random.Random(7))
    assert clock.t - t0 == d1
    nominal = 0.2 + 0.4 + 0.8
    assert nominal * 0.5 <= d1 < nominal


def test_membership_and_straggler_steal():
    svc = CoordService(4)
    clock = ManualClock()
    mem = Membership(svc, heartbeat_ttl=0.5, clock=clock)
    for n in range(3):
        mem.join(n)
    assert mem.alive() == [0, 1, 2]
    owned0 = mem.assign_shards(0, 9)
    assert len(owned0) == 3
    # node 0 heartbeated within the TTL: the steal must abort (a late
    # heartbeat racing a premature steal_from), leaving ownership intact
    kept = mem.steal_from(2, dead_node=0)
    assert set(kept).isdisjoint(owned0)
    assert [s for s, n in svc.get("shards").items() if n == 0] == owned0
    # past the TTL node 0 really is dead and the steal goes through
    clock.advance(0.6)
    mem.heartbeat(2)
    stolen = mem.steal_from(2, dead_node=0)
    assert set(owned0) <= set(stolen)
    mem.heartbeat(1)
    assert mem.alive() == [1, 2]

"""Simulator reproduces the paper's qualitative claims (trend-level).

These are the Fig. 1/4/5 sanity anchors; the quantitative sweeps live in
benchmarks/ (one per paper figure).
"""
import numpy as np

from repro.core.cost_model import CostModel
from repro.core.sim import SimConfig, simulate

EV = 120_000


def thr(alg, nodes, tpn, locks, loc, b=(5, 20), ev=EV):
    return simulate(SimConfig(alg, nodes, tpn, locks, loc, b),
                    n_events=ev).throughput_mops


def test_alock_wins_at_full_locality():
    """§6.2: at 100% locality ALock does shared-memory-only ops and beats
    loopback-based competitors by a large factor."""
    a = thr("alock", 5, 4, 20, 1.0)
    s = thr("spinlock", 5, 4, 20, 1.0)
    m = thr("mcs", 5, 4, 20, 1.0)
    assert a > 4 * s, (a, s)
    assert a > 4 * m, (a, m)


def test_alock_wins_high_locality_high_contention():
    a = thr("alock", 5, 8, 20, 0.95)
    s = thr("spinlock", 5, 8, 20, 0.95)
    assert a > 2 * s, (a, s)


def test_loopback_spinlock_saturates_with_threads():
    """Fig. 1: single-node loopback spinlock throughput collapses past a
    few threads (PCIe/RX pressure), while ALock keeps scaling."""
    lo = thr("spinlock", 1, 2, 100, 1.0)
    hi = thr("spinlock", 1, 12, 100, 1.0)
    assert hi < lo, (lo, hi)
    a_lo = thr("alock", 1, 2, 100, 1.0)
    a_hi = thr("alock", 1, 12, 100, 1.0)
    assert a_hi > a_lo, (a_lo, a_hi)


def test_remote_budget_amortizes_reacquire():
    """Fig. 4 direction: budgets trade fairness ops for throughput. Tight
    budgets force frequent (expensive, remote-spinning) reacquires; raising
    the remote budget recovers the loss. Magnitudes are calibration-
    dependent (see EXPERIMENTS.md §fig4); the ordering is the claim."""
    ev = 200_000
    tight = thr("alock", 20, 12, 100, 0.9, b=(1, 1), ev=ev)
    mid = thr("alock", 20, 12, 100, 0.9, b=(2, 8), ev=ev)
    tuned = thr("alock", 20, 12, 100, 0.9, b=(5, 20), ev=ev)
    assert tuned > 1.10 * tight, (tight, tuned)
    assert mid > tight, (tight, mid)
    # (5,20) never materially worse than the paper's (5,5) baseline
    base = thr("alock", 20, 12, 100, 0.9, b=(5, 5), ev=ev)
    assert tuned >= 0.98 * base


def test_budget_reacquire_mechanism_fires():
    """Counter-level check of the mechanism: tighter budgets => more
    pReacquire events; lock passing dominates under contention."""
    from repro.core.sim import SimConfig, simulate
    r_tight = simulate(SimConfig("alock", 20, 12, 100, 0.9, (1, 1)),
                       n_events=150_000)
    r_loose = simulate(SimConfig("alock", 20, 12, 100, 0.9, (5, 20)),
                       n_events=150_000)
    assert r_tight.reacquires > 3 * max(r_loose.reacquires, 1)
    assert r_loose.passes > r_loose.ops // 3


def test_latency_samples_reasonable():
    r = simulate(SimConfig("alock", 5, 4, 100, 0.95), n_events=EV)
    lats = np.asarray(r.lat_ns)
    lats = lats[lats >= 0]
    # an op is >= think + cs + a couple of accesses
    cm = CostModel()
    assert np.median(lats) > cm.cs_ns
    assert np.median(lats) < 1e6  # < 1ms at this scale


def test_qp_thrash_penalizes_loopback_algs():
    cm = CostModel()
    f_alock = cm.thrash_factor(20, 12, uses_loopback=False)
    f_spin = cm.thrash_factor(20, 12, uses_loopback=True)
    assert f_spin >= f_alock >= 1.0

"""Per-arch reduced-config smoke tests: forward + one train step on CPU,
asserting output shapes and finiteness (assignment §f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, all_arch_names, cell_supported, get_config
from repro.models import model as M
from repro.models.params import init_tree, param_count
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step

ARCHS = all_arch_names()


def tiny_batch(cfg, B=2, S=32, key=jax.random.key(0)):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32),
    }
    if cfg.n_vision_tokens:
        batch["vision_embeds"] = jnp.ones(
            (B, cfg.n_vision_tokens, cfg.d_model), cfg.dtype) * 0.01
    if cfg.is_encdec:
        batch["enc_embeds"] = jnp.ones((B, cfg.enc_seq, cfg.d_model),
                                       cfg.dtype) * 0.01
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).tiny()
    params = init_tree(M.model_specs(cfg), jax.random.key(0))
    assert param_count(M.model_specs(cfg)) > 1000
    batch = tiny_batch(cfg)
    logits, aux, _ = M.forward(cfg, params, batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    # padded vocab ids are masked to -1e9
    assert float(logits[..., cfg.vocab:].max()) < -1e8


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch):
    cfg = get_config(arch).tiny()
    params = init_tree(M.model_specs(cfg), jax.random.key(0))
    opt = init_opt_state(params)
    step_fn = jax.jit(make_train_step(cfg, OptConfig(warmup_steps=2,
                                                     total_steps=10)))
    batch = tiny_batch(cfg)
    params, opt, metrics = step_fn(params, opt, batch,
                                   jnp.zeros((), jnp.int32))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    gn = metrics["grad_norm"]
    assert float(gn) > 0
    # a second step keeps everything finite
    params, opt, metrics = step_fn(params, opt, batch,
                                   jnp.ones((), jnp.int32))
    assert bool(jnp.isfinite(metrics["loss"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """Decode with a ring cache must reproduce teacher-forcing logits."""
    cfg = get_config(arch).tiny()
    params = init_tree(M.model_specs(cfg), jax.random.key(1))
    B, S, E = 2, 24, 3
    key = jax.random.key(7)
    toks = jax.random.randint(key, (B, S + E), 0, cfg.vocab, jnp.int32)
    batch0 = {"tokens": toks[:, :S]}
    if cfg.is_encdec:
        batch0["enc_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 9), (B, cfg.enc_seq, cfg.d_model),
            jnp.float32) * 0.1
    if cfg.n_vision_tokens:
        batch0["vision_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 8), (B, cfg.n_vision_tokens,
                                         cfg.d_model), jnp.float32) * 0.1
    batch_full = dict(batch0, tokens=toks)
    logits_full, _, _ = M.forward(cfg, params, batch_full)
    lg, cache = M.prefill(cfg, params, batch0, cache_len=S + E)
    errs = [float(jnp.abs(lg - logits_full[:, S - 1]).max())]
    for i in range(E):
        lg, cache = M.decode_step(cfg, params, cache,
                                  toks[:, S + i:S + i + 1],
                                  jnp.asarray(S + i, jnp.int32))
        errs.append(float(jnp.abs(lg - logits_full[:, S + i]).max()))
    assert max(errs) < 2e-3, errs


def test_cell_support_table():
    """40 assigned cells: 34 runnable + 6 documented long_500k skips."""
    cells = [(a, s) for a in ARCHS for s in SHAPES]
    assert len(cells) == 40
    skips = [(a, s) for a, s in cells if not cell_supported(a, s)[0]]
    assert len(skips) == 6
    assert all(s == "long_500k" for _, s in skips)

"""ExecOptions, Experiment builder, scenario registry, coord stress +
deterministic (injected-clock) lease/membership behavior."""
import dataclasses

import numpy as np
import pytest

from repro.coord.service import CoordService, LeaseManager, Membership
from repro.coord.stress import ManualClock, run_coord_stress
from repro.core import batch
from repro.experiments import (ExecOptions, Experiment, get_scenario,
                               run_scenario, scenario_names)
from repro.workloads import Phase, Workload

EV = 800


# -- ExecOptions ------------------------------------------------------------


def test_exec_options_validation_and_immutability():
    with pytest.raises(ValueError, match="backend"):
        ExecOptions(backend="cuda")
    with pytest.raises(ValueError, match="devices"):
        ExecOptions(devices=0)
    with pytest.raises(ValueError, match="chunk"):
        ExecOptions(chunk=-1)
    o = ExecOptions(backend="xla", chunk=2)
    with pytest.raises(dataclasses.FrozenInstanceError):
        o.backend = "pallas"
    kw = o.sweep_kwargs()
    assert kw == {"backend": "xla", "devices": None, "chunk": 2}


def test_exec_options_device_list_bounds():
    with pytest.raises(ValueError, match="device"):
        ExecOptions(devices=4096).device_list()
    assert ExecOptions().device_list() is None


def test_exec_options_from_env(monkeypatch):
    monkeypatch.setenv("REPRO_BACKEND", "xla")
    assert ExecOptions.from_env().backend == "xla"
    assert ExecOptions.from_env(backend="pallas").backend == "pallas"
    # an unset CLI flag arrives as backend=None: the env var must win
    # (regression: setdefault on an existing None key ignored the env)
    assert ExecOptions.from_env(backend=None, devices=None).backend == "xla"


# -- Experiment -------------------------------------------------------------


def test_experiment_grid_labels_dedupe_and_results():
    base = Workload("alock", 2, 2, 8, locality=0.9)
    exp = (Experiment("t", n_seeds=2, n_events=EV,
                      options=ExecOptions(backend="xla"))
           .add_grid(base, alg=("alock", "mcs"), locality=(0.85, 1.0))
           .add(base, label="extra"))
    assert len(exp) == 5
    assert exp.labels if hasattr(exp, "labels") else True
    with pytest.raises(ValueError, match="duplicate"):
        exp.add(base, label="extra")
    res = exp.run()
    assert res.labels == ["alock.locality0.85", "alock.locality1",
                          "mcs.locality0.85", "mcs.locality1", "extra"]
    # result rows equal a direct sweep of the same specs
    direct = batch.sweep([base.replace(alg="mcs", locality=1.0)],
                         n_seeds=2, n_events=EV, backend="xla")[0]
    np.testing.assert_array_equal(res["mcs.locality1"].ops, direct.ops)
    np.testing.assert_array_equal(res["mcs.locality1"].lat_ns,
                                  direct.lat_ns)
    # addressable by spec too, and SimConfig keys ride the adapter
    assert res[base] is res["extra"]


# -- scenario registry ------------------------------------------------------


def test_registry_names_and_unknown():
    names = scenario_names()
    for expected in ("uniform-grid", "hot-key-storm", "mixed-locality",
                     "node-churn", "paper-fig5", "coord-stress",
                     "limping-node", "fail-slow-cascade"):
        assert expected in names
    with pytest.raises(ValueError, match="unknown scenario"):
        get_scenario("nope")


def test_run_scenario_rows_smoke():
    rows = run_scenario("node-churn", n_seeds=1, n_events=600,
                        options=ExecOptions(backend="xla"))
    assert all({"name", "us_per_call", "derived"} <= set(r) for r in rows)
    assert any("node3_op_share" in r["name"] for r in rows)


def test_fail_slow_scenarios_report_per_node_rows():
    """Non-uniform node_mult workloads break throughput out per node;
    uniform (healthy) workloads keep the per-alg aggregate only."""
    rows = run_scenario("limping-node", n_seeds=1, n_events=600,
                        options=ExecOptions(backend="xla"))
    names = [r["name"] for r in rows]
    for n in range(4):
        assert f"alock.hot.limp.node{n}" in names
    assert not any(n.startswith("alock.hot.healthy.node") for n in names)
    limp0 = next(r for r in rows if r["name"] == "alock.hot.limp.node0")
    assert limp0["node_mult_max"] == 4.0
    assert 0.0 < limp0["node_op_share"] < 1.0
    assert any(n.endswith("limp_throughput_ratio") for n in names)
    # the cascade's per-phase program also counts as non-uniform
    rows = run_scenario("fail-slow-cascade", n_seeds=1, n_events=600,
                        options=ExecOptions(backend="xla"))
    names = [r["name"] for r in rows]
    assert "mcs.cascade.node3" in names
    assert not any(n.startswith("mcs.healthy.node") for n in names)


# -- coord stress through the workload spec ---------------------------------


def _churn_workload(seed=0):
    return Workload("alock", 3, 4, 12, locality=0.9, seed=seed,
                    phases=(Phase(frac=0.3),
                            Phase(frac=0.4, down_nodes=(2,), zipf_s=2.0),
                            Phase(frac=0.3)))


def test_coord_stress_deterministic_and_churn_shaped():
    r1 = run_coord_stress(_churn_workload(), ops_per_thread=30,
                          clock=ManualClock())
    r2 = run_coord_stress(_churn_workload(), ops_per_thread=30,
                          clock=ManualClock())
    assert r1.ops == r2.ops and r1.per_node_ops == r2.per_node_ops
    assert r1.lease_grants == r2.lease_grants
    assert r1.lease_steals == r2.lease_steals
    # contended names exercise the bounded-retry path, deterministically
    assert r1.lease_retries == r2.lease_retries > 0
    # node 2 vanishes from phase-1 membership and does fewer lock ops
    assert r1.phase_members == [[0, 1, 2], [0, 1], [0, 1, 2]]
    assert r1.per_node_ops[2] < min(r1.per_node_ops[0],
                                    r1.per_node_ops[1])
    assert r1.lease_steals > 0        # expiry storms turn leases over


# -- injected clocks (satellite: no sleeps, fully deterministic) ------------


def test_lease_expiry_storm_with_manual_clock():
    clock = ManualClock()
    svc = CoordService(4)
    lm = LeaseManager(svc, ttl_s=5.0, clock=clock)
    l0 = lm.acquire(0, "ckpt")
    assert l0 is not None and l0.epoch == 0
    assert lm.acquire(1, "ckpt") is None          # exclusive while live
    clock.advance(2.0)
    assert lm.renew(l0)                           # deadline pushed out
    clock.advance(4.0)
    assert lm.acquire(1, "ckpt") is None          # renew kept it alive
    clock.advance(5.1)                            # ...now it expires
    l1 = lm.acquire(1, "ckpt")
    assert l1 is not None and l1.epoch == l0.epoch + 1
    assert not lm.renew(l0)                       # old epoch fenced off


def test_membership_with_manual_clock():
    clock = ManualClock()
    svc = CoordService(4)
    mem = Membership(svc, heartbeat_ttl=2.0, clock=clock)
    for n in range(3):
        mem.join(n)
    assert mem.alive() == [0, 1, 2]
    clock.advance(1.5)
    mem.heartbeat(1)
    clock.advance(1.0)                            # 0/2 stale, 1 fresh
    assert mem.alive() == [1]

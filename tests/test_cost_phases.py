"""Phase-dependent cost model + ALock budget programs (PR 4 tentpole).

Contracts under test:
  1. default-profile bitwise freeze: COST_PROFILES["default"] lowers to
     exactly the rows the pre-profile ``topology()`` computed, and a
     default-cost Workload's operands carry them verbatim;
  2. phase-boundary budget handoff: acquisitions arm with the ``b_init``
     of the phase active at the arming event; budgets granted before a
     boundary keep draining across it (xla + pallas bitwise);
  3. per-phase cost rows change the dynamics (congested burst slows the
     loopback algs) while staying bitwise-equal across backends and
     bucket-mixable without extra compiles;
  4. ``pad_phases`` stays inert now that cost/budget rows are per-phase;
  5. spec validation of the new ``cost`` / ``b_init`` fields;
  6. the ``--check-slo`` exit-code gate (subprocess, smoke events).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import batch
from repro.core.cost_model import (COST_PROFILES, CostModel, CostProfile,
                                   resolve_cost)
from repro.core.sim import simulate, topology
from repro.workloads import Phase, Workload, lower, pad_phases

EV = 1200


def _assert_same(rx, rp):
    assert rx.ops == rp.ops
    assert rx.sim_ns == rp.sim_ns
    assert rx.reacquires == rp.reacquires
    assert rx.passes == rp.passes
    np.testing.assert_array_equal(np.asarray(rx.lat_ns),
                                  np.asarray(rp.lat_ns))
    np.testing.assert_array_equal(np.asarray(rx.per_thread_ops),
                                  np.asarray(rp.per_thread_ops))


# -- 1. default-profile provenance / bitwise freeze -------------------------


@pytest.mark.parametrize("alg", ["alock", "spinlock", "mcs"])
@pytest.mark.parametrize("n,tpn", [(2, 2), (20, 12)])
def test_default_profile_matches_pre_change_cost_rows(alg, n, tpn):
    """COST_PROFILES['default'] must reproduce the pre-profile cost_rows
    bit for bit — the exact int(round(...)) arithmetic topology() used
    before CostProfile existed."""
    cm = CostModel()
    uses_loopback = alg != "alock"
    legacy = tuple(int(round(v)) for v in (
        cm.local_ns, cm.spin_poll_ns, cm.cs_ns, cm.think_ns,
        cm.svc_ns(n, tpn, uses_loopback, False),
        cm.svc_ns(n, tpn, uses_loopback, True),
        cm.remote_wire_ns, cm.loopback_wire_ns))
    assert COST_PROFILES["default"].cost_rows(alg, n, tpn) == legacy
    assert cm.cost_rows(alg, n, tpn) == legacy
    _, _, topo_costs = topology(alg, n, tpn, n * 2)
    assert tuple(topo_costs) == legacy
    # and a default-cost workload lowers to exactly these rows
    o = lower(Workload(alg, n, tpn, n * 2), n_events=100).operands
    np.testing.assert_array_equal(o.cost_rows,
                                  np.int32(legacy)[None, :])


def test_default_profile_is_field_identical_to_costmodel():
    cm, prof = CostModel(), COST_PROFILES["default"]
    import dataclasses
    for f in dataclasses.fields(CostModel):
        assert getattr(prof, f.name) == getattr(cm, f.name), f.name


def test_resolve_cost_forms():
    base = CostModel()
    assert resolve_cost(None, base) is base
    assert resolve_cost("congested-nic", base) \
        is COST_PROFILES["congested-nic"]
    over = resolve_cost((("rnic_svc_ns", 999.0),), base)
    assert over.rnic_svc_ns == 999.0 and over.local_ns == base.local_ns
    with pytest.raises(ValueError, match="unknown cost profile"):
        resolve_cost("warp-drive", base)


# -- 2. phase-boundary budget handoff ---------------------------------------


def test_budget_program_rearms_at_phase_b_init():
    """Tight budgets in phase 0 force reacquire churn; a generous phase 1
    must stop it. The split run's counters sit strictly between the
    constant-tight and constant-generous controls."""
    base = Workload("alock", 2, 4, 8, locality=0.5, seed=3)
    tight = base.replace(b_init=(1, 1))
    loose = base.replace(b_init=(50, 50))
    split = base.replace(b_init=(1, 1), phases=(
        Phase(frac=0.5), Phase(frac=0.5, b_init=(50, 50))))
    ev = 4_000
    r_t = simulate(tight, n_events=ev)
    r_l = simulate(loose, n_events=ev)
    r_s = simulate(split, n_events=ev)
    assert r_t.reacquires > 10                 # the mechanism fires at all
    assert r_l.reacquires < r_t.reacquires // 4
    assert r_l.reacquires <= r_s.reacquires <= r_t.reacquires
    # the generous half really suppressed churn: the split run does far
    # fewer reacquires than a full-length tight run
    assert r_s.reacquires < 0.8 * r_t.reacquires


def test_budget_handoff_bitwise_xla_pallas():
    """The budget program through both engines, including a boundary that
    lands mid event-chunk, is bitwise identical."""
    w = Workload("alock", 2, 4, 8, locality=0.5, seed=7, b_init=(1, 2),
                 phases=(Phase(frac=0.37), Phase(frac=0.33, b_init=(9, 40)),
                         Phase(frac=0.30, b_init=(2, 2))))
    _assert_same(simulate(w, n_events=EV, backend="xla"),
                 simulate(w, n_events=EV, backend="pallas"))


def test_phase_b_init_none_inherits_workload():
    w = Workload("alock", 2, 2, 8, b_init=(3, 7),
                 phases=(Phase(frac=0.5), Phase(frac=0.5, b_init=(8, 9))))
    o = lower(w, n_events=100).operands
    np.testing.assert_array_equal(o.b_init, [[3, 7], [8, 9]])


# -- 3. per-phase cost rows --------------------------------------------------


def test_congested_phase_slows_loopback_alg_and_is_bitwise():
    base = Workload("mcs", 2, 4, 8, locality=1.0, seed=1)
    burst = base.replace(phases=(Phase(frac=0.3),
                                 Phase(frac=0.4, cost="congested-nic"),
                                 Phase(frac=0.3)))
    ev = 4_000
    r0 = simulate(base, n_events=ev)
    r1 = simulate(burst, n_events=ev)
    assert r1.ops < r0.ops            # congestion costs completed ops
    assert r1.sim_ns > r0.sim_ns      # ... and simulated time
    _assert_same(simulate(burst, n_events=EV, backend="xla"),
                 simulate(burst, n_events=EV, backend="pallas"))


def test_workload_level_cost_applies_to_all_phases():
    w = Workload("mcs", 2, 2, 8, cost="congested-nic",
                 phases=(Phase(frac=0.5), Phase(frac=0.5, cost="default")))
    o = lower(w, n_events=100).operands
    cong = COST_PROFILES["congested-nic"].cost_rows("mcs", 2, 2)
    dflt = COST_PROFILES["default"].cost_rows("mcs", 2, 2)
    np.testing.assert_array_equal(o.cost_rows[0], np.int32(cong))
    np.testing.assert_array_equal(o.cost_rows[1], np.int32(dflt))


def test_cost_override_mapping_lowered():
    w = Workload("alock", 2, 2, 8, cost={"rnic_svc_ns": 999.0})
    o = lower(w, n_events=100).operands
    assert o.cost_rows[0, 4] == 999 and o.cost_rows[0, 0] == 100


def test_mixed_cost_profiles_share_one_compile():
    """Workloads under different cost profiles and budget programs still
    bucket into ONE executable (cost rows are traced operands)."""
    cfgs = [
        Workload("alock", 2, 2, 8, locality=0.9, seed=1),
        Workload("alock", 2, 2, 8, locality=0.9, cost="congested-nic"),
        Workload("alock", 2, 2, 8, locality=0.9, cost="idle-nic",
                 b_init=(1, 1)),
        Workload("alock", 2, 2, 8, locality=0.9,
                 phases=(Phase(frac=0.5, b_init=(1, 1)),
                         Phase(frac=0.5, cost="congested-nic"))),
    ]
    batch.reset_exec_stats()
    res = batch.sweep(cfgs, n_seeds=2, n_events=EV, backend="xla")
    st = batch.exec_stats()
    assert st["dispatches"] == 1 and st["compiles"] <= 1
    # the default-cost member is bitwise-equal to its solo run
    solo = simulate(cfgs[0], n_events=EV, backend="xla")
    assert int(res[0].ops[0]) == solo.ops
    np.testing.assert_array_equal(res[0].lat_ns[0], np.asarray(solo.lat_ns))
    # ... and the whole mixed bucket agrees across backends
    rp = batch.sweep(cfgs, n_seeds=2, n_events=EV, backend="pallas")
    for a, b in zip(res, rp):
        np.testing.assert_array_equal(a.ops, b.ops)
        np.testing.assert_array_equal(a.lat_ns, b.lat_ns)


# -- 4. pad_phases inertness over cost/budget rows ---------------------------


def test_pad_phases_inert_for_cost_and_budget_rows():
    """Engine-level inertness: padding a 2-phase cost/budget program to 5
    phases must not change a single bit of the run."""
    w = Workload("alock", 2, 2, 8, locality=0.9, seed=5, b_init=(2, 3),
                 phases=(Phase(frac=0.5, cost="idle-nic", b_init=(1, 4)),
                         Phase(frac=0.5, cost="congested-nic")))
    lw = lower(w, n_events=EV)
    padded = pad_phases(lw.operands, 5)
    assert padded.cost_rows.shape == (5, 8)
    assert padded.b_init.shape == (5, 2)
    from jax.experimental import enable_x64
    import jax.numpy as jnp
    from repro.kernels.event_loop.ref import run_events_ref
    from repro.workloads import WorkloadOperands
    tn, ln, _ = topology("alock", 2, 2, 8)
    with enable_x64():
        outs = []
        for ops in (lw.operands, padded):
            wl = WorkloadOperands(*(jnp.asarray(a)[None] for a in ops))
            outs.append(run_events_ref("alock", 4, 2, 8, EV, wl, tn, ln))
    for a, b in zip(*outs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- 5. spec validation ------------------------------------------------------


def test_cost_and_b_init_spec_validation():
    with pytest.raises(ValueError, match="unknown cost profile"):
        Workload("alock", 2, 2, 8, cost="warp-drive")
    with pytest.raises(ValueError, match="unknown cost-model field"):
        Workload("alock", 2, 2, 8, cost={"wire_speed": 1.0})
    with pytest.raises(ValueError, match="b_init"):
        Phase(frac=0.5, b_init=(1, 2, 3))
    with pytest.raises(ValueError, match=">= 0"):
        Phase(frac=0.5, b_init=(-1, 2))
    with pytest.raises(ValueError, match="unknown cost profile"):
        Phase(frac=0.5, cost="nope")
    # frozen specs stay hashable with the new fields
    w1 = Workload("alock", 2, 2, 8, cost="congested-nic",
                  phases=(Phase(frac=0.5, b_init=(1, 1)),
                          Phase(frac=0.5)))
    w2 = Workload("alock", 2, 2, 8, cost="congested-nic",
                  phases=(Phase(frac=0.5, b_init=(1, 1)),
                          Phase(frac=0.5)))
    assert w1 == w2 and hash(w1) == hash(w2)
    assert {w1: 1}[w2] == 1
    # dict overrides canonicalize to a hashable sorted tuple
    w3 = Workload("alock", 2, 2, 8, cost={"rnic_svc_ns": 999.0})
    assert w3.cost == (("rnic_svc_ns", 999.0),)
    assert hash(w3) == hash(w3.replace())


def test_profile_instances_ride_specs():
    prof = CostProfile(name="custom", rnic_svc_ns=500.0)
    w = Workload("alock", 2, 2, 8, cost=prof)
    o = lower(w, n_events=50).operands
    assert o.cost_rows[0, 4] == 500


# -- 6. --check-slo exit-code gate (subprocess) ------------------------------


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_bench(*args):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               REPRO_BENCH_EVENTS="800", JAX_PLATFORMS="cpu")
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run", *args],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=600)


def test_check_slo_pass_and_fail_exit_codes(tmp_path):
    out = tmp_path / "rows.json"
    ok = _run_bench("--scenario", "budget-ramp", "--seeds", "1",
                    "--check-slo", "--scenario-out", str(out))
    assert ok.returncode == 0, ok.stdout + ok.stderr
    assert "slo budget-ramp: PASS" in ok.stdout
    rows = json.loads(out.read_text())
    assert any("p99_lat_ns" in r for r in rows)
    assert any("events_per_sec" in r for r in rows)

    bad = _run_bench("--scenario", "budget-ramp", "--seeds", "1",
                     "--check-slo", "--slo-p99-ns", "1")
    assert bad.returncode == 1, bad.stdout + bad.stderr
    assert "VIOLATION" in bad.stdout


def test_check_slo_requires_scenario():
    r = _run_bench("--check-slo")
    assert r.returncode == 2          # argparse error

"""JAX machine/simulator equivalence + Pallas kernel allclose sweeps."""
import numpy as np
import numpy.random as npr
import pytest
import jax
import jax.numpy as jnp
from hypothesis_compat import given, settings, st

from repro.core import machine as mc
from repro.core.sim import SimConfig, run_schedule, simulate


@pytest.mark.parametrize("alg", ["alock", "mcs", "spinlock"])
def test_jnp_machine_matches_python(alg):
    rng = npr.default_rng(0)
    cohorts = (0, 0, 1, 1)
    sched = rng.integers(0, 4, 2000)
    st_ = mc.initial_state(4)
    pcs = []
    for tid in sched:
        st_, _ = mc.MACHINES[alg](st_, int(tid), cohorts[tid], (2, 3))
        pcs.append(st_.pc)
    _, trace = run_schedule(alg, cohorts, (2, 3), sched)
    assert (np.asarray(pcs) == np.asarray(trace[0])).all()


@given(st.integers(0, 2**31 - 1), st.sampled_from(["alock", "mcs"]))
@settings(max_examples=8)
def test_jnp_machine_matches_python_hypothesis(seed, alg):
    rng = npr.default_rng(seed)
    cohorts = tuple(rng.integers(0, 2, 3).tolist())
    sched = rng.integers(0, 3, 500)
    st_ = mc.initial_state(3)
    for tid in sched:
        st_, _ = mc.MACHINES[alg](st_, int(tid), cohorts[tid], (1, 2))
    sem, _ = run_schedule(alg, cohorts, (1, 2), sched)
    assert tuple(np.asarray(sem.pc)) == st_.pc
    assert tuple(np.asarray(sem.budget)) == st_.budget
    if alg == "alock":
        assert tuple(np.asarray(sem.tail[0])) == st_.tail


def test_event_sim_runs_and_counts():
    r = simulate(SimConfig("alock", 2, 2, 8, 0.9), n_events=60_000)
    assert r.ops > 100
    lats = np.asarray(r.lat_ns)
    lats = lats[lats >= 0]
    assert len(lats) > 50 and (lats > 0).all()


# ---------------------------------------------------------------------------
# Pallas kernels vs oracles (interpret mode on CPU)


@pytest.mark.parametrize("S,hd,dtype", [(128, 64, jnp.float32),
                                        (256, 128, jnp.float32),
                                        (128, 64, jnp.bfloat16)])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 32)])
def test_flash_kernel_sweep(S, hd, dtype, causal, window):
    from repro.kernels.flash_attention.kernel import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    key = jax.random.key(0)
    B, H = 2, 2
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, H, S, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 2), (B, H, S, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 3), (B, H, S, hd), dtype)
    o1 = flash_attention(q, k, v, causal=causal, window=window, bq=64,
                         bk=64, interpret=True)
    o2 = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("S,H,P,N,chunk", [(64, 4, 16, 8, 16),
                                           (128, 2, 32, 16, 32),
                                           (32, 8, 8, 4, 8)])
def test_ssd_kernel_sweep(S, H, P, N, chunk):
    from repro.kernels.ssd_scan.ops import ssd_forward
    from repro.kernels.ssd_scan.ref import ssd_sequential
    key = jax.random.key(1)
    B = 2
    xh = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 2),
                                           (B, S, H)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 3), (H,)) * 0.3)
    b = jax.random.normal(jax.random.fold_in(key, 4), (B, S, N)) * 0.5
    c = jax.random.normal(jax.random.fold_in(key, 5), (B, S, N)) * 0.5
    y0, h0 = ssd_sequential(xh, dt, a, b, c)
    y1, h1 = ssd_forward(xh, dt, a, b, c, chunk=chunk, hb=min(2, H),
                         interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=2e-4,
                               rtol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h0), atol=2e-4,
                               rtol=2e-4)


def _run_tick_vs_machine(rng_seed, Tab, T, steps, tile):
    """Run alock_tick on fresh tables and assert every table's final
    (pc, tail, budget) matches the Python machine oracle."""
    from repro.kernels.alock_tick.kernel import alock_tick
    rng = npr.default_rng(rng_seed)
    cohorts = rng.integers(0, 2, T).astype(np.int32)
    sched = rng.integers(0, T, (Tab, steps)).astype(np.int32)
    b_init = (2, 3)
    z = lambda: jnp.zeros((Tab, T), jnp.int32)
    out = alock_tick(
        jnp.zeros((Tab, 2), jnp.int32), jnp.zeros((Tab, 1), jnp.int32),
        jnp.full((Tab, T), mc.NCS, jnp.int32),
        jnp.full((Tab, T), -1, jnp.int32), z(), z(),
        jnp.asarray(sched), jnp.broadcast_to(jnp.asarray(cohorts), (Tab, T)),
        b_init=b_init, tile=tile, interpret=True)
    assert all(o.shape[0] == Tab for o in out)
    for t in range(Tab):
        st_ = mc.initial_state(T)
        for tid in sched[t]:
            st_, _ = mc.alock_step(st_, int(tid), int(cohorts[tid]), b_init)
        assert tuple(np.asarray(out[2][t])) == st_.pc
        assert tuple(np.asarray(out[0][t])) == st_.tail
        assert tuple(np.asarray(out[3][t])) == st_.budget


def test_alock_tick_kernel_matches_machine():
    _run_tick_vs_machine(rng_seed=5, Tab=8, T=4, steps=300, tile=4)


def test_alock_tick_kernel_pads_nonmultiple_tables():
    """Tab not divisible by tile (e.g. 300 tables, tile 128) must pad the
    batch internally and slice back, not crash."""
    _run_tick_vs_machine(rng_seed=11, Tab=6, T=3, steps=150, tile=4)


def test_flash_bwd_kernels_match_oracle():
    from repro.kernels.flash_attention.ops import mha_vjp
    from repro.kernels.flash_attention.ref import attention_ref
    key = jax.random.key(0)
    B, H, S, hd = 2, 2, 64, 16
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, H, S, hd))
    k = jax.random.normal(jax.random.fold_in(key, 2), (B, H, S, hd))
    v = jax.random.normal(jax.random.fold_in(key, 3), (B, H, S, hd))
    for causal, window in ((True, None), (True, 16), (False, None)):
        def f1(q, k, v):
            return mha_vjp(q, k, v, causal=causal, window=window, bq=16,
                           bk=16, interpret=True).sum()

        def f2(q, k, v):
            return attention_ref(q, k, v, causal=causal,
                                 window=window).sum()
        g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-5, rtol=2e-5)

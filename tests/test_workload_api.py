"""The tentpole contracts of the Workload redesign.

1. SimConfig-adapter bitwise equality: every alg x locality x zipf point
   from ``test_event_loop_kernel.py`` produces bit-identical results
   whether expressed as a flat ``SimConfig`` or as an explicit ``Workload``
   (scalar, per-thread vector, or single-phase form) — on both backends.
2. Traced-operand bucketing: a sweep mixing >= 3 scenarios (flat,
   per-thread mix, multi-phase program) runs as ONE dispatch + ONE compile
   per shape bucket (``batch.exec_stats``), with the flat member still
   bitwise-equal to its solo ``simulate`` run.
3. Phase semantics: hot-key storms raise contention; a downed node loses
   its share of completed ops (rejoin resumes from the cluster clock).
"""
import numpy as np
import pytest

from repro.core import batch
from repro.core.sim import SimConfig, simulate
from repro.workloads import Phase, Workload, from_simconfig, mixed

EV = 1000

POINTS = [("alock", 0.85, 0.0), ("alock", 1.0, 0.0),
          ("spinlock", 0.85, 0.0), ("spinlock", 1.0, 0.0),
          ("mcs", 0.85, 0.0), ("mcs", 1.0, 0.0)]


def _cfg(alg, loc, zipf):
    if zipf:
        return SimConfig(alg, 3, 4, 6, loc, (5, 20), seed=3, zipf_s=zipf)
    return SimConfig(alg, 2, 2, 8, loc, (2, 3), seed=7)


def _assert_same(rx, rp):
    assert rx.ops == rp.ops
    assert rx.sim_ns == rp.sim_ns
    assert rx.reacquires == rp.reacquires
    assert rx.passes == rp.passes
    np.testing.assert_array_equal(np.asarray(rx.lat_ns),
                                  np.asarray(rp.lat_ns))
    np.testing.assert_array_equal(np.asarray(rx.per_thread_ops),
                                  np.asarray(rp.per_thread_ops))


def _spec_variants(cfg):
    w = from_simconfig(cfg)
    T = w.n_threads
    return (w,                                            # adapter
            w.replace(locality=(float(cfg.locality),) * T),  # (T,) vector
            w.replace(phases=(Phase(frac=1.0),)))         # explicit phase


@pytest.mark.parametrize("alg,loc,zipf", POINTS + [("alock", 0.9, 1.2)])
def test_adapter_and_spec_forms_bitwise_xla(alg, loc, zipf):
    cfg = _cfg(alg, loc, zipf)
    base = simulate(cfg, n_events=EV, backend="xla")
    for w in _spec_variants(cfg):
        _assert_same(base, simulate(w, n_events=EV, backend="xla"))


@pytest.mark.parametrize("alg,loc,zipf",
                         [("alock", 0.85, 0.0), ("spinlock", 1.0, 0.0),
                          ("mcs", 0.85, 0.0), ("alock", 0.9, 1.2)])
def test_adapter_and_spec_forms_bitwise_pallas(alg, loc, zipf):
    """The SimConfig adapter path and the explicit spec forms also agree
    through the Pallas kernel (interpret mode on CPU)."""
    cfg = _cfg(alg, loc, zipf)
    base = simulate(cfg, n_events=EV, backend="xla")
    for w in _spec_variants(cfg):
        _assert_same(base, simulate(w, n_events=EV, backend="pallas"))


def test_sweep_mixing_scenarios_is_one_compile_one_dispatch():
    """>= 3 scenarios of one topology — flat adapter config, per-thread
    mix, phased hot-key storm, churn program — share a single executable
    and a single dispatch; phase padding is provably inert for the flat
    member."""
    flat_cfg = SimConfig("alock", 2, 2, 8, 0.9, (2, 3), seed=7)
    scenarios = [
        flat_cfg,                                          # adapter
        Workload("alock", 2, 2, 8,
                 locality=mixed(local=0.95, frac=0.5, rest=0.2)),
        Workload("alock", 2, 2, 8, locality=0.9,
                 phases=(Phase(frac=0.5), Phase(frac=0.5, zipf_s=3.0))),
        Workload("alock", 2, 2, 8, locality=0.9,
                 phases=(Phase(frac=0.3),
                         Phase(frac=0.4, down_nodes=(1,)),
                         Phase(frac=0.3))),
    ]
    batch.reset_exec_stats()
    res = batch.sweep(scenarios, n_seeds=2, n_events=EV, backend="xla")
    st = batch.exec_stats()
    assert st["dispatches"] == 1 and st["compiles"] <= 1
    solo = simulate(flat_cfg, n_events=EV, backend="xla")
    assert int(res[0].ops[0]) == solo.ops
    assert int(res[0].sim_ns[0]) == solo.sim_ns
    np.testing.assert_array_equal(res[0].lat_ns[0], np.asarray(solo.lat_ns))
    # and the same mixed bucket through the pallas backend agrees
    rp = batch.sweep(scenarios, n_seeds=2, n_events=EV, backend="pallas")
    for a, b in zip(res, rp):
        np.testing.assert_array_equal(a.ops, b.ops)
        np.testing.assert_array_equal(a.lat_ns, b.lat_ns)


def test_hot_key_storm_raises_contention():
    base = Workload("alock", 2, 2, 8, locality=1.0)
    storm = base.replace(phases=(Phase(frac=0.3),
                                 Phase(frac=0.4, zipf_s=4.0),
                                 Phase(frac=0.3)))
    r0 = simulate(base, n_events=6_000)
    r1 = simulate(storm, n_events=6_000)
    assert r0.ops > 0 and r1.ops > 0
    assert r1.ops < r0.ops            # serialized hot lock completes less
    assert batch.shape_key(base, 6_000) == batch.shape_key(storm, 6_000)


def test_downed_node_loses_op_share():
    churn = Workload("alock", 4, 4, 16, locality=0.95, seed=5,
                     phases=(Phase(frac=0.3),
                             Phase(frac=0.4, down_nodes=(3,)),
                             Phase(frac=0.3)))
    r = simulate(churn, n_events=4_000)
    pto = np.asarray(r.per_thread_ops)
    node3 = float(pto[12:].sum())
    assert node3 > 0                          # it was up 60% of the run
    share = node3 / float(pto.sum())
    assert share < 0.22                       # well under the fair 0.25


def test_single_masked_phase_parks_threads_everywhere():
    """A one-phase program with down_nodes must park those threads in
    every execution layout (regression: the engines' single-phase fast
    path used to drop the active mask, so results depended on which
    workloads shared the sweep bucket)."""
    w = Workload("alock", 2, 2, 4, locality=0.9, seed=3,
                 phases=(Phase(frac=1.0, down_nodes=(1,)),))
    r = simulate(w, n_events=EV, backend="xla")
    pto = np.asarray(r.per_thread_ops)
    assert pto[:2].sum() > 0 and pto[2:].sum() == 0
    _assert_same(r, simulate(w, n_events=EV, backend="pallas"))
    solo = batch.sweep([w], n_seeds=1, n_events=EV, backend="xla")[0]
    mixed_bucket = batch.sweep(
        [w, Workload("alock", 2, 2, 4, locality=0.9,
                     phases=(Phase(frac=0.4), Phase(frac=0.3),
                             Phase(frac=0.3)))],
        n_seeds=1, n_events=EV, backend="xla")[0]
    np.testing.assert_array_equal(solo.per_thread_ops,
                                  mixed_bucket.per_thread_ops)
    np.testing.assert_array_equal(solo.lat_ns, mixed_bucket.lat_ns)
    np.testing.assert_array_equal(pto, solo.per_thread_ops[0])


def test_per_thread_locality_shapes_traffic():
    """Threads with locality 1.0 never take the remote-cohort path while
    their 0.0-locality peers on the same node mostly do (alock cohorts)."""
    w = Workload("alock", 2, 2, 4, locality=(1.0, 0.0, 1.0, 0.0), seed=2)
    r = simulate(w, n_events=4_000)
    pto = np.asarray(r.per_thread_ops)
    assert pto.sum() == r.ops and (pto >= 0).all()
    # local-only threads complete strictly more ops than remote-only ones
    assert pto[0] + pto[2] > pto[1] + pto[3]

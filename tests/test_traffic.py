"""Acceptance tests for the open-loop traffic engine (``repro.traffic``).

The contracts the serving benchmarks lean on:

  * **Little's law** — on a stable Poisson stream the time-average number
    in system equals arrival rate x mean sojourn, cross-checked against an
    independent sampled estimate of N(t);
  * **deterministic trace replay** — an ``Arrivals(trace_ns=...)`` stream
    reproduces the recorded arrival times exactly, bitwise-identically
    across repeat runs, seeds, and both engines;
  * **drop-accounting conservation** — across phase edges and under both
    admission policies (bounded queue tail drop, token bucket):
    ``arrived == completed + dropped + in_service + queued``, with every
    per-slot status consistent with its wait/sojourn stamps;
  * **closed-loop inertness** — a spec without ``arrivals`` lowers to
    ``R == 0`` and carries no per-request arrays anywhere.
"""
import numpy as np
import pytest

from repro.core import batch
from repro.core.sim import simulate
from repro.traffic.metrics import (COMPLETED, DROPPED, IN_SERVICE, PENDING,
                                   serving_summary)
from repro.workloads import Arrivals, Phase, Workload, lower


def _summary(r):
    return serving_summary(r.arr_ns, r.wait_ns, r.sojourn_ns, r.rstat,
                           r.sim_ns)


# -- Little's law -----------------------------------------------------------


def test_littles_law_on_stable_poisson():
    """L = lambda x W on a Poisson stream well under the service capacity.

    ``mean_concurrency`` integrates completed sojourns over the window;
    the product of the goodput rate and the mean sojourn must match it
    (the law), and an *independent* estimate — sampling N(t) on a time
    grid — must land on the same value, which checks the integral against
    the actual arrival/departure interval structure rather than the same
    arithmetic twice.
    """
    w = Workload("alock", 2, 2, 8, locality=0.9, seed=1,
                 arrivals=Arrivals(rate_per_us=0.5, max_requests=128))
    r = simulate(w, n_events=4000, backend="xla")
    s = _summary(r)
    assert s["dropped"] == 0                    # no admission policy armed
    assert s["completed"] > 32                  # enough mass to average
    lam_ns = s["goodput_per_us"] / 1e3          # completions per ns
    assert s["mean_concurrency"] == pytest.approx(
        lam_ns * s["mean_sojourn_ns"], rel=1e-9)
    # independent N(t) estimate: count requests in system on a time grid
    arr = np.asarray(r.arr_ns)
    soj = np.asarray(r.sojourn_ns)
    comp = np.asarray(r.rstat) == COMPLETED
    dep = np.where(comp, arr + soj, -1)
    t = np.linspace(0, r.sim_ns, 4001)
    n_t = ((arr[None, :] <= t[:, None]) & (dep[None, :] > t[:, None])
           & comp[None, :]).sum(axis=1)
    assert float(n_t.mean()) == pytest.approx(s["mean_concurrency"],
                                              rel=0.05, abs=0.05)
    # stable regime: the service keeps up with the offered load
    assert s["goodput_per_us"] >= 0.8 * s["offered_per_us"]


# -- deterministic trace replay ---------------------------------------------


def test_trace_replay_bitwise_deterministic():
    """A pure trace (``rate_per_us == 0``) replays the recorded arrival
    times exactly — across repeat runs, across the replica seed (the
    Poisson jitter term is identically zero), and bitwise across both
    engines."""
    trace = tuple(range(0, 12000, 800))         # 15 arrivals, 0.8us apart
    w = Workload("mcs", 2, 2, 8, locality=0.9, seed=7,
                 arrivals=Arrivals(trace_ns=trace))
    r1 = simulate(w, n_events=900, backend="xla")
    r2 = simulate(w, n_events=900, backend="xla")
    np.testing.assert_array_equal(np.asarray(r1.arr_ns), np.int64(trace))
    for a, b in zip((r1.arr_ns, r1.wait_ns, r1.sojourn_ns, r1.rstat),
                    (r2.arr_ns, r2.wait_ns, r2.sojourn_ns, r2.rstat)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the seed re-draws the event stream but never the replayed arrivals
    r3 = simulate(w.replace(seed=8), n_events=900, backend="xla")
    np.testing.assert_array_equal(np.asarray(r3.arr_ns),
                                  np.asarray(r1.arr_ns))
    # engine cross-check: the Pallas kernel replays the same trace bitwise
    rp = simulate(w, n_events=900, backend="pallas")
    for name, a, b in (("arr", r1.arr_ns, rp.arr_ns),
                       ("wq", r1.wait_ns, rp.wait_ns),
                       ("soj", r1.sojourn_ns, rp.sojourn_ns),
                       ("rstat", r1.rstat, rp.rstat)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"trace replay: {name}")


# -- drop-accounting conservation -------------------------------------------

_BURST = (Phase(frac=0.4), Phase(frac=0.2, rate_per_us=16.0),
          Phase(frac=0.4))

_POLICIES = {
    "queue": Arrivals(rate_per_us=1.0, max_requests=160, queue_cap=4),
    "token": Arrivals(rate_per_us=1.0, max_requests=160,
                      token_rate_per_us=1.0, token_burst=2.0),
}


@pytest.mark.parametrize("policy", sorted(_POLICIES))
def test_drop_conservation_across_phase_edges(policy):
    """A 16x mid-run burst against each admission policy: requests really
    drop, and every arrival inside the window is accounted for exactly
    once — ``arrived == completed + dropped + in_service + queued`` — with
    per-slot stamps consistent with the status codes."""
    w = Workload("alock", 2, 2, 8, locality=0.9, seed=3, phases=_BURST,
                 arrivals=_POLICIES[policy])
    r = simulate(w, n_events=4000, backend="xla")
    s = _summary(r)
    assert s["dropped"] > 0, s
    assert s["arrived"] == (s["completed"] + s["dropped"]
                            + s["in_service"] + s["queued"])
    assert s["queued"] >= 0 and s["in_service"] >= 0
    arr = np.asarray(r.arr_ns)
    wq = np.asarray(r.wait_ns)
    soj = np.asarray(r.sojourn_ns)
    st = np.asarray(r.rstat)
    inside = arr <= r.sim_ns
    # the residual really is the pending-inside-window population
    assert int(((st == PENDING) & inside).sum()) == s["queued"]
    # completions carry both stamps, and service time is non-negative
    np.testing.assert_array_equal(st == COMPLETED, soj >= 0)
    assert (wq[st == COMPLETED] >= 0).all()
    assert (soj[st == COMPLETED] >= wq[st == COMPLETED]).all()
    # drops never got dispatched: no wait, no sojourn
    assert (wq[st == DROPPED] == -1).all()
    assert (soj[st == DROPPED] == -1).all()
    # in-service requests were dispatched but never finished
    assert (wq[st == IN_SERVICE] >= 0).all()
    assert (soj[st == IN_SERVICE] == -1).all()
    # slots past the window never materialize (event-bounded run)
    assert (st[~inside] == PENDING).all()


def test_unbounded_queue_never_drops():
    """The same burst with no admission policy: zero drops, backlog only
    (the control the burst-storm scenario reports ratios against)."""
    w = Workload("alock", 2, 2, 8, locality=0.9, seed=3, phases=_BURST,
                 arrivals=Arrivals(rate_per_us=1.0, max_requests=160))
    s = _summary(simulate(w, n_events=4000, backend="xla"))
    assert s["dropped"] == 0
    assert s["arrived"] == s["completed"] + s["in_service"] + s["queued"]


# -- batch plumbing ---------------------------------------------------------


def test_sweep_carries_serving_arrays_and_matches_pallas():
    """``batch.sweep`` surfaces the per-request arrays per seed and both
    backends agree bitwise through the full sweep path (bucketing,
    padding, chunked dispatch)."""
    ws = [Workload("alock", 2, 2, 8, locality=0.9,
                   arrivals=Arrivals(rate_per_us=1.0, max_requests=48,
                                     queue_cap=8)),
          Workload("alock", 2, 2, 8, locality=0.5,
                   arrivals=Arrivals(rate_per_us=2.0, max_requests=48))]
    rx = batch.sweep(ws, n_seeds=2, n_events=1200, backend="xla")
    rp = batch.sweep(ws, n_seeds=2, n_events=1200, backend="pallas")
    for bx, bp in zip(rx, rp):
        assert bx.open_loop and bp.open_loop
        assert bx.arr_ns.shape == (2, 48)
        for f in ("arr_ns", "wait_ns", "sojourn_ns", "rstat"):
            np.testing.assert_array_equal(getattr(bx, f), getattr(bp, f),
                                          err_msg=f"sweep {f}")
        sm = bx.serving_mean()
        assert sm["arrived"] > 0 and np.isfinite(sm["goodput_per_us"])


# -- closed-loop inertness --------------------------------------------------


def test_closed_loop_stays_inert():
    """No ``arrivals`` -> ``R == 0`` in the compile bucket, no per-request
    outputs anywhere, and ``serving()`` refuses cleanly."""
    w = Workload("alock", 2, 2, 8, locality=0.9)
    assert lower(w, 500).shape_key[-1] == 0
    r = simulate(w, n_events=500, backend="xla")
    assert r.arr_ns is None and r.wait_ns is None
    assert r.sojourn_ns is None and r.rstat is None
    br = batch.sweep([w], n_seeds=1, n_events=500, backend="xla")[0]
    assert not br.open_loop
    with pytest.raises(ValueError, match="open-loop"):
        br.serving(0)

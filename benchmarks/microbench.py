"""Lock-primitive microbenchmarks: operation counts + kernel wall time.

Uncontended op counts per Lock()+Unlock() (measured on the machine, not
assumed): ALock-local = 0 RDMA ops; ALock-remote = 4 RDMA (swap, victim,
read, release-CAS); competitors pay RDMA/loopback on every access.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import machine as mc


def count_ops(alg, cohort):
    st = mc.initial_state(1)
    remote = local = 0
    guard = 0
    while True:
        st, op = mc.MACHINES[alg](st, 0, cohort, (5, 20))
        if op.kind == "remote":
            remote += 1
        elif op.kind == "local":
            local += 1
        guard += 1
        if st.pc[0] == mc.NCS and guard > 1:
            break
        assert guard < 100
    return remote, local


def bench_wall(f, *args, iters=5):
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def main() -> None:
    for alg, cohort, name in (("alock", 0, "alock.local"),
                              ("alock", 1, "alock.remote"),
                              ("mcs", 1, "mcs"),
                              ("spinlock", 1, "spinlock")):
        r, l = count_ops(alg, cohort)
        emit(f"micro.opcount.{name}", 0.0, f"remote_ops={r},local_ops={l}")

    # jnp flash (model path) vs naive attention wall time on CPU
    from repro.models.layers import _mask, _sdpa_h, blockwise_sdpa
    B, S, K, R, hd = 1, 1024, 4, 1, 64
    key = jax.random.key(0)
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, S, K, R, hd))
    k = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, hd))
    v = jax.random.normal(jax.random.fold_in(key, 3), (B, S, K, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))

    f1 = jax.jit(lambda q, k, v: blockwise_sdpa(
        q, k, v, pos, causal=True, window=None, kv_chunk=256))
    us1 = bench_wall(f1, q, k, v)
    emit("micro.attn.flash_jnp.s1024", us1, "blockwise")

    def naive(q, k, v):
        m = _mask(pos, jnp.arange(S), causal=True, window=None)
        return _sdpa_h(q.reshape(B, S, K * R, hd), jnp.repeat(k, R, 2),
                       jnp.repeat(v, R, 2), m)
    us2 = bench_wall(jax.jit(naive), q, k, v)
    emit("micro.attn.naive.s1024", us2, f"flash_speedup={us2/us1:.2f}x")

    # batched lock-table transition throughput (jnp twin of the kernel)
    from repro.kernels.alock_tick.ref import alock_tick_ref
    Tab, T, steps = 512, 4, 256
    rng = np.random.default_rng(0)
    sched = jnp.asarray(rng.integers(0, T, (Tab, steps)), jnp.int32)
    coh = jnp.asarray([0, 0, 1, 1], jnp.int32)
    z = lambda: jnp.zeros((Tab, T), jnp.int32)
    args = (jnp.zeros((Tab, 2), jnp.int32), jnp.zeros((Tab,), jnp.int32),
            jnp.full((Tab, T), mc.NCS, jnp.int32),
            jnp.full((Tab, T), -1, jnp.int32), z(), z())
    f3 = jax.jit(lambda *a: alock_tick_ref(*a, sched, coh,
                                           jnp.asarray((5, 20), jnp.int32)))
    us3 = bench_wall(f3, *args, iters=3)
    emit("micro.alock_tick.tables512.steps256", us3,
         f"{Tab*steps/us3:.1f}Msteps_per_s")


if __name__ == "__main__":
    main()

"""Lock-primitive microbenchmarks: operation counts + kernel wall time.

Uncontended op counts per Lock()+Unlock() for **every** registered state
machine (measured by stepping ``repro.core.machine``, not assumed).
Table 1's headline — ALock-local issues **0 RDMA ops** — is a *checked*
output: the process exits non-zero if the local path ever issues a
remote op, so a machine regression fails ``benchmarks.run`` instead of
silently changing a printed number. ``hlock`` shares ALock's machine
(the caller derives the cohort from the rack topology) and is checked
to the same local-path claim; ``alock-rw`` is counted on both the
writer path (full ALock protocol + reader drain) and the reader path
(queue bypass).

Kernel wall time: one small bucket through the event-loop Pallas kernel
(interpret mode — the CPU CI stand-in) vs the vmapped XLA oracle via
``batch.sweep``. These are wall-us rows for eyeballing, not trajectory
gates — ``benchmarks/perfcheck.py`` owns the gated trajectory.
"""
import sys
import time

import jax

from benchmarks.common import EVENTS, emit
from repro.core import machine as mc

#: checked op-count claims: row name -> exact expected remote-op count.
#: ALock/hlock local-cohort acquire+release must be RDMA-free (Table 1).
CHECKED = {"alock.local": 0, "hlock.local": 0,
           "alock-rw.writer.local": 0, "alock-rw.reader.local": 0}


def count_ops(alg, cohort, is_read=False):
    """(remote, local) op counts for one uncontended Lock()+Unlock()."""
    st = mc.initial_state(1)
    step = mc.MACHINES[alg]
    remote = local = guard = 0
    while True:
        if alg == "alock-rw":
            st, op = step(st, 0, cohort, (5, 20), is_read=is_read)
        else:
            st, op = step(st, 0, cohort, (5, 20))
        if op.kind == "remote":
            remote += 1
        elif op.kind == "local":
            local += 1
        guard += 1
        if st.pc[0] == mc.NCS and guard > 1:
            break
        assert guard < 100
    return remote, local


def bench_wall(f, *args, iters=5):
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / iters * 1e6


#: (alg, cohort, is_read, row name) — every machine in mc.MACHINES
OPCOUNT_ROWS = (
    ("alock", 0, False, "alock.local"),
    ("alock", 1, False, "alock.remote"),
    ("hlock", 0, False, "hlock.local"),
    ("hlock", 1, False, "hlock.remote"),
    ("alock-rw", 0, False, "alock-rw.writer.local"),
    ("alock-rw", 1, False, "alock-rw.writer.remote"),
    ("alock-rw", 0, True, "alock-rw.reader.local"),
    ("alock-rw", 1, True, "alock-rw.reader.remote"),
    ("mcs", 1, False, "mcs"),
    ("spinlock", 1, False, "spinlock"),
)


def main() -> None:
    failed = []
    for alg, cohort, is_read, name in OPCOUNT_ROWS:
        r, l = count_ops(alg, cohort, is_read=is_read)
        verdict = ""
        if name in CHECKED:
            ok = r == CHECKED[name]
            verdict = f",checked={'ok' if ok else 'FAIL'}"
            if not ok:
                failed.append(f"{name}: expected {CHECKED[name]} remote "
                              f"ops, measured {r}")
        emit(f"micro.opcount.{name}", 0.0,
             f"remote_ops={r},local_ops={l}{verdict}")

    # event-loop kernel vs the XLA oracle on one small bucket (wall time;
    # interpret mode is the CPU stand-in for the Pallas path)
    from repro.core import batch
    from repro.workloads import Workload
    ev = min(EVENTS, 20_000)
    cfgs = [Workload("alock", 2, 2, 8, locality=0.95)]
    walls = {}
    for backend in ("xla", "pallas"):
        walls[backend] = bench_wall(
            lambda b=backend: batch.sweep(cfgs, n_seeds=1, n_events=ev,
                                          backend=b), iters=2)
        emit(f"micro.kernel.{backend}.ev{ev}", walls[backend],
             f"{ev / walls[backend]:.2f}Mev/s")
    emit("micro.kernel.pallas_over_xla", 0.0,
         f"{walls['xla'] / max(walls['pallas'], 1e-9):.2f}x")

    if failed:
        for msg in failed:
            print(f"# microbench CHECK FAILED: {msg}", flush=True)
        sys.exit(1)


if __name__ == "__main__":
    main()

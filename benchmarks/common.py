"""Shared helpers for the benchmark suite.

Every benchmark prints CSV rows: name,us_per_call,derived
  - us_per_call: mean microseconds of acquire->release latency per
    lock+unlock op (simulated time, think_ns excluded — Fig. 6 semantics),
    or wall time per call for kernel benches
  - derived: the figure-specific statistic; simulator rows report
    mean±ci95 across seeds (ci95 is 0.000 for a single seed)

All simulator figures are built on the declarative Workload/Experiment
API: each ``fig*`` section composes ``repro.workloads.Workload`` specs
(per-thread locality, Zipf skew, phases) into a
``repro.experiments.Experiment`` and runs them as one batched sweep —
bucketed by shape key ``(alg, T, N, K, n_events)``, one compile per
bucket, all workload shape as traced operands. Named scenario programs
(``benchmarks.run --scenario``) come from the registry in
``repro.experiments.registry``.

Execution choices (backend, device sharding, chunking) travel as an
explicit immutable ``repro.experiments.ExecOptions`` value, threaded from
``benchmarks.run`` into every section — there is no process-wide mutable
execution state (the old ``EXEC`` module global is gone).
"""
from __future__ import annotations

import os

import numpy as np

from repro.core.batch import BatchResult, sweep
from repro.core.sim import SimResult, simulate
from repro.experiments import ExecOptions, Experiment
from repro.workloads import Workload

# Paper-scale default; REPRO_BENCH_EVENTS=2000 gives a fast smoke pass with
# identical bucketing/compile behavior (n_events is part of the shape key).
EVENTS = int(os.environ.get("REPRO_BENCH_EVENTS", 150_000))


def wl(alg, nodes, tpn, locks, loc, b=(5, 20), seed=0,
       zipf=0.0, phases=()) -> Workload:
    return Workload(alg, nodes, tpn, locks, locality=loc, zipf_s=zipf,
                    b_init=b, seed=seed, phases=phases)


def experiment(name: str, n_seeds: int = 1, events: int = EVENTS,
               options: ExecOptions | None = None) -> Experiment:
    """An Experiment wired to the suite's defaults (env backend honored)."""
    return Experiment(name, n_seeds=n_seeds, n_events=events,
                      options=options or ExecOptions.from_env())


def run(alg, nodes, tpn, locks, loc, b=(5, 20), events=EVENTS, seed=0,
        options: ExecOptions | None = None) -> SimResult:
    """One-off serial run (kept for interactive use; figures use sweep)."""
    options = options or ExecOptions.from_env()
    return simulate(wl(alg, nodes, tpn, locks, loc, b, seed),
                    n_events=events, backend=options.backend)


def sweep_all(cfgs, n_seeds: int = 1, events: int = EVENTS,
              options: ExecOptions | None = None) -> dict:
    """Batched run of deduped ``cfgs``; returns {workload: BatchResult}."""
    options = options or ExecOptions.from_env()
    uniq = list(dict.fromkeys(cfgs))
    return dict(zip(uniq, sweep(uniq, n_seeds=n_seeds, n_events=events,
                                **options.sweep_kwargs())))


def us_per_op(r) -> float:
    """Mean acquire->release latency in us (SimResult or BatchResult)."""
    if isinstance(r, BatchResult):
        return r.mean_lat_us
    lat = np.asarray(r.lat_ns)
    lat = lat[lat >= 0]
    return float(lat.mean()) / 1e3 if len(lat) else float("nan")


def mops(br: BatchResult) -> str:
    return f"{br.mean_mops:.3f}±{br.ci95_mops:.3f}Mops"


def emit(name: str, us: float, derived) -> None:
    print(f"{name},{us:.3f},{derived}", flush=True)

"""Shared helpers for the benchmark suite.

Every benchmark prints CSV rows: name,us_per_call,derived
  - us_per_call: mean microseconds of acquire->release latency per
    lock+unlock op (simulated time, think_ns excluded — Fig. 6 semantics),
    or wall time per call for kernel benches
  - derived: the figure-specific statistic; simulator rows report
    mean±ci95 across seeds (ci95 is 0.000 for a single seed)

All simulator figures route through ``repro.core.batch.sweep``: configs are
built up front and bucketed by shape key ``(alg, T, N, K, n_events)``, so
each bucket compiles once and runs its whole locality/budget/seed batch as
one vmapped device call. Pass ``--seeds N`` to ``benchmarks.run`` for
error bars; ``--backend xla|pallas``, ``--devices N`` and ``--chunk R``
select the execution backend and the sharded bucket layout (see
``core/batch.py``) for every section at once.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core.batch import BatchResult, sweep
from repro.core.sim import SimConfig, SimResult, simulate

# Paper-scale default; REPRO_BENCH_EVENTS=2000 gives a fast smoke pass with
# identical bucketing/compile behavior (n_events is part of the shape key).
EVENTS = int(os.environ.get("REPRO_BENCH_EVENTS", 150_000))

# Suite-wide execution options, set once by benchmarks.run (or env) and
# honored by every sweep_all/run call.
EXEC = {
    "backend": os.environ.get("REPRO_BACKEND", "auto"),
    "devices": None,   # int: shard sweeps over jax.devices()[:n]
    "chunk": None,     # int: rows per device per dispatch
}


def set_exec_options(backend=None, devices=None, chunk=None) -> None:
    """Install suite-wide backend/sharding choices (None = leave as is)."""
    if backend is not None:
        EXEC["backend"] = backend
    if devices is not None:
        EXEC["devices"] = int(devices)
    if chunk is not None:
        EXEC["chunk"] = int(chunk)


def _devices():
    if EXEC["devices"] is None:
        return None
    import jax
    n = EXEC["devices"]
    devs = jax.devices()
    if n > len(devs):
        raise ValueError(f"--devices {n} but only {len(devs)} JAX device(s) "
                         f"are visible")
    return devs[:n]


def cfg(alg, nodes, tpn, locks, loc, b=(5, 20), seed=0,
        zipf=0.0) -> SimConfig:
    return SimConfig(alg, nodes, tpn, locks, loc, b, seed, zipf)


def run(alg, nodes, tpn, locks, loc, b=(5, 20), events=EVENTS,
        seed=0) -> SimResult:
    """One-off serial run (kept for interactive use; figures use sweep)."""
    return simulate(SimConfig(alg, nodes, tpn, locks, loc, b, seed),
                    n_events=events, backend=EXEC["backend"])


def sweep_all(cfgs, n_seeds: int = 1, events: int = EVENTS) -> dict:
    """Batched run of deduped ``cfgs``; returns {SimConfig: BatchResult}."""
    uniq = list(dict.fromkeys(cfgs))
    return dict(zip(uniq, sweep(uniq, n_seeds=n_seeds, n_events=events,
                                backend=EXEC["backend"], devices=_devices(),
                                chunk=EXEC["chunk"])))


def us_per_op(r) -> float:
    """Mean acquire->release latency in us (SimResult or BatchResult)."""
    if isinstance(r, BatchResult):
        return r.mean_lat_us
    lat = np.asarray(r.lat_ns)
    lat = lat[lat >= 0]
    return float(lat.mean()) / 1e3 if len(lat) else float("nan")


def mops(br: BatchResult) -> str:
    return f"{br.mean_mops:.3f}±{br.ci95_mops:.3f}Mops"


def emit(name: str, us: float, derived) -> None:
    print(f"{name},{us:.3f},{derived}", flush=True)

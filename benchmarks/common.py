"""Shared helpers for the benchmark suite.

Every benchmark prints CSV rows: name,us_per_call,derived
  - us_per_call: mean microseconds per lock+unlock op (simulated time), or
    wall time per call for kernel benches
  - derived: the figure-specific statistic (throughput, speedup, ...)
"""
from __future__ import annotations

import numpy as np

from repro.core.sim import SimConfig, SimResult, simulate

EVENTS = 150_000


def run(alg, nodes, tpn, locks, loc, b=(5, 20), events=EVENTS,
        seed=0) -> SimResult:
    return simulate(SimConfig(alg, nodes, tpn, locks, loc, b, seed),
                    n_events=events)


def us_per_op(r: SimResult) -> float:
    lat = np.asarray(r.lat_ns)
    lat = lat[lat >= 0]
    return float(lat.mean()) / 1e3 if len(lat) else float("nan")


def emit(name: str, us: float, derived) -> None:
    print(f"{name},{us:.3f},{derived}", flush=True)

"""Perf trajectory check: aggregate simulator events/sec, recorded per PR.

Runs a Fig.5-shaped grid through ``batch.sweep`` once per execution backend
(XLA fori_loop vs the Pallas event-loop kernel) and once through the
chunked/sharded bucket layout, then writes ``BENCH_events_per_sec.json`` so
every PR leaves an events/sec data point behind (CI uploads it as an
artifact).

Measured quantities:
  * events/sec per backend (warm: one untimed sweep first, so compile cost
    is reported separately and the steady-state rate is comparable PR to
    PR);
  * dispatch/compile counts from ``batch.exec_stats`` — the chunked layout
    must show one dispatch per chunk per mesh (vs one per bucket) while
    reusing a single compile per shape key, which is the CPU-visible half
    of the scaling story (on TPU the pallas backend's events/sec carries
    it);
  * the event-loop kernel's VMEM plan (``repro.kernels.event_loop.vmem``,
    via ``exec_stats()["vmem_plan"]``) for the pallas backend — replica
    tile chosen vs requested, total VMEM bytes, clock representation — so
    every PR records whether the kernel still fits the budget and whether
    the planner had to shrink the tile.

Smoke mode: REPRO_BENCH_EVENTS=2000 (same knob as the other benchmarks).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import EVENTS
from repro.core import batch
from repro.experiments import fig5_workloads

LOCALITY = (0.85, 0.95, 1.0)


def _grid():
    # the registry's paper-fig5 grid: perfcheck and --scenario paper-fig5
    # measure the identical workload program
    return fig5_workloads()


def _timed_sweep(cfgs, n_seeds, events, **kw):
    """(results, wall_s of the warm run, stats) — stats carries the warm
    run's dispatch count plus the cold (first) run's compile count."""
    batch.reset_exec_stats()
    batch.sweep(cfgs, n_seeds=n_seeds, n_events=events, **kw)  # warm/compile
    cold = batch.exec_stats()
    batch.reset_exec_stats()
    t0 = time.perf_counter()
    res = batch.sweep(cfgs, n_seeds=n_seeds, n_events=events, **kw)
    wall = time.perf_counter() - t0
    st = batch.exec_stats()
    st["compiles"] = cold["compiles"]
    return res, wall, st


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backends", default="xla,pallas",
                    help="comma list of backends to measure")
    ap.add_argument("--events", type=int, default=EVENTS)
    ap.add_argument("--seeds", type=int, default=1)
    ap.add_argument("--chunk", type=int, default=None,
                    help="rows/device/dispatch for the sharded leg "
                         "(default: half a bucket, forcing 2 chunks)")
    ap.add_argument("--out", default="BENCH_events_per_sec.json")
    args = ap.parse_args()

    cfgs = _grid()
    n_buckets = len({batch.shape_key(c, args.events) for c in cfgs})
    total_events = len(cfgs) * args.seeds * args.events
    report = {
        "scenario": "paper-fig5",
        "grid": {"configs": len(cfgs), "seeds": args.seeds,
                 "events_per_replica": args.events,
                 "total_events": total_events, "buckets": n_buckets},
        "backends": {},
    }

    base = None     # (backend, results) of the first measured backend
    base_xla = None  # unsharded XLA results — the oracle both legs diff to
    for backend in [b.strip() for b in args.backends.split(",") if b.strip()]:
        res, wall, st = _timed_sweep(cfgs, args.seeds, args.events,
                                     backend=backend)
        eps = total_events / max(wall, 1e-9)
        report["backends"][backend] = {
            "wall_s": round(wall, 4), "events_per_sec": round(eps, 1),
            "dispatches": st["dispatches"], "compiles": st["compiles"],
            "vmem_plan": st.get("vmem_plan"),
        }
        print(f"perfcheck.{backend},{wall*1e6/len(cfgs):.1f},"
              f"{eps/1e6:.3f}Mevents/s", flush=True)
        if backend == "xla":
            base_xla = res
        if base is None:
            base = (backend, res)
        else:
            same = all(np.array_equal(a.lat_ns, b.lat_ns)
                       and np.array_equal(a.ops, b.ops)
                       for a, b in zip(base[1], res))
            report["backends"][backend]["bitwise_equal_to_" + base[0]] = same
    if base_xla is None:
        # the sharded leg below runs on xla, so its bitwise check needs an
        # unsharded xla oracle even when --backends skipped it (untimed)
        base_xla = batch.sweep(cfgs, n_seeds=args.seeds,
                               n_events=args.events, backend="xla")

    # sharded/chunked layout: one dispatch per chunk (per mesh), one compile
    # per shape key — dispatch-count evidence that oversized buckets spill
    # into fixed-size chunks instead of recompiling
    bucket_rows = max(args.seeds * len(LOCALITY), 1)
    chunk = args.chunk or max(1, -(-bucket_rows // 2))
    res_c, wall_c, st_c = _timed_sweep(cfgs, args.seeds, args.events,
                                       backend="xla", chunk=chunk)
    eq = all(
        np.array_equal(a.lat_ns, b.lat_ns) and np.array_equal(a.ops, b.ops)
        for a, b in zip(base_xla, res_c))
    report["sharding"] = {
        "chunk_rows_per_device": chunk,
        "bucket_rows": bucket_rows,
        "wall_s": round(wall_c, 4),
        "events_per_sec": round(total_events / max(wall_c, 1e-9), 1),
        "dispatches": st_c["dispatches"],
        "compiles": st_c["compiles"],
        "unsharded_dispatches_per_bucket": 1,
        "bitwise_equal_to_unsharded": bool(eq),
    }
    print(f"perfcheck.sharded.chunk{chunk},{wall_c*1e6/len(cfgs):.1f},"
          f"dispatches={st_c['dispatches']},compiles={st_c['compiles']},"
          f"bitwise_ok={eq}", flush=True)

    bk = report["backends"]
    if "xla" in bk and "pallas" in bk:
        report["pallas_over_xla"] = round(
            bk["pallas"]["events_per_sec"] / max(bk["xla"]["events_per_sec"],
                                                 1e-9), 3)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"# wrote {args.out}", flush=True)


if __name__ == "__main__":
    main()

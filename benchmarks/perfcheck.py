"""Perf trajectory check: aggregate simulator events/sec, recorded per PR.

Runs a Fig.5-shaped grid through ``batch.sweep`` once per execution backend
(XLA fori_loop vs the Pallas event-loop kernel) and once through the
chunked/sharded bucket layout, then writes ``BENCH_events_per_sec.json`` so
every PR leaves an events/sec data point behind (CI uploads it as an
artifact).

Measured quantities:
  * events/sec per backend (warm: one untimed sweep first, so compile cost
    is reported separately and the steady-state rate is comparable PR to
    PR);
  * an open-loop serving row: the same topology driven by a fixed-rate
    Poisson arrival stream (``repro.traffic``), recording offered vs
    achieved request rate and the harness events/sec of the open-loop
    code path — so the arrival-ingestion lanes show up in the perf
    trajectory, not only in the scenario JSONs;
  * a cross-algorithm leaderboard leg: all five registered algorithms
    (alock, spinlock, mcs, hlock with a 2-rack topology, alock-rw at a
    0.9 read mix) swept on one shared grid and ranked by simulated
    throughput — each algorithm's mean Mops is its own tracked trajectory
    row, so kernel-path regressions in the hierarchical or reader-writer
    designs trip the ``--baseline`` gate even though the Fig.5 grid never
    dispatches them;
  * dispatch/compile counts from ``batch.exec_stats`` — the chunked layout
    must show one dispatch per chunk per mesh (vs one per bucket) while
    reusing a single compile per shape key, which is the CPU-visible half
    of the scaling story (on TPU the pallas backend's events/sec carries
    it);
  * the event-loop kernel's VMEM plan (``repro.kernels.event_loop.vmem``,
    via ``exec_stats()["vmem_plan"]``) for the pallas backend — replica
    tile chosen vs requested, total VMEM bytes, clock representation — so
    every PR records whether the kernel still fits the budget and whether
    the planner had to shrink the tile;
  * a roofline row (``benchmarks.roofline``): the events/sec-per-byte
    ceiling from the VMEM byte table and a *measured* host copy
    bandwidth, plus the fraction of that roof the fastest backend
    achieved — a tracked trajectory row, so an efficiency regression
    trips the ``--baseline`` gate even if absolute ev/s drifts with the
    runner.

The sharded leg also records ``ratio_vs_unsharded`` (sharded ev/s over
the unsharded XLA leg); ``--min-sharded-ratio`` turns that into a hard
gate — superchunk dispatch coalescing keeps the chunked layout near the
one-dispatch layout on CPU, and CI fails if it slides back.

``--baseline FILE`` compares the fresh report against a previous run's
JSON (CI downloads the last ``BENCH_events_per_sec.json`` artifact and
passes it here): every tracked events/sec figure must stay within
``--regression-tolerance`` (default 10%) of the baseline, or the process
exits non-zero. A missing baseline file, or one measured with a
different grid/event count, is reported and skipped — the first run of a
new trajectory cannot regress against anything.

Smoke mode: REPRO_BENCH_EVENTS=2000 (same knob as the other benchmarks).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from benchmarks import roofline
from benchmarks.common import EVENTS
from repro.core import batch
from repro.experiments import fig5_workloads
from repro.workloads import Arrivals, Workload, racks_of

LOCALITY = (0.85, 0.95, 1.0)

# the open-loop leg: the scenario topology under a fixed-rate Poisson
# stream — a rate below ALock's knee but above the loopback designs', so
# the row shows both regimes (tracked vs shed) in one line each
OPEN_RATE_PER_US = 4.0
OPEN_REQS = 256
OPEN_QCAP = 32
OPEN_ALGS = ("alock", "mcs")

# the leaderboard leg: all five registered algorithms on one shared
# topology (2 racks x 2 nodes for hlock's tiering, a 0.9 read mix for
# alock-rw's shared section) — every algorithm's simulated throughput is
# its own trajectory row, so a perf regression in the hlock or alock-rw
# kernel paths trips the --baseline gate even though paper-fig5 never
# dispatches them
LB_ALGS = ("alock", "spinlock", "mcs", "hlock", "alock-rw")
LB_READ_FRAC = 0.9
LB_LOCALITY = 0.95


def _leaderboard_grid():
    racks = racks_of(4, 2)
    out = []
    for alg in LB_ALGS:
        kw = {}
        if alg == "hlock":
            kw["topology"] = racks
        if alg == "alock-rw":
            kw["read_frac"] = LB_READ_FRAC
        out.append(Workload(alg, n_nodes=4, threads_per_node=4, n_locks=16,
                            locality=LB_LOCALITY, **kw))
    return out


def _open_grid():
    arr = Arrivals(rate_per_us=OPEN_RATE_PER_US, max_requests=OPEN_REQS,
                   queue_cap=OPEN_QCAP)
    return [Workload(alg, n_nodes=4, threads_per_node=4, n_locks=16,
                     locality=0.95, arrivals=arr) for alg in OPEN_ALGS]


def _grid():
    # the registry's paper-fig5 grid: perfcheck and --scenario paper-fig5
    # measure the identical workload program
    return fig5_workloads()


def _timed_sweep(cfgs, n_seeds, events, **kw):
    """(results, wall_s of the warm run, stats) — stats carries the warm
    run's dispatch count plus the cold (first) run's compile count."""
    batch.reset_exec_stats()
    batch.sweep(cfgs, n_seeds=n_seeds, n_events=events, **kw)  # warm/compile
    cold = batch.exec_stats()
    batch.reset_exec_stats()
    t0 = time.perf_counter()
    res = batch.sweep(cfgs, n_seeds=n_seeds, n_events=events, **kw)
    wall = time.perf_counter() - t0
    st = batch.exec_stats()
    st["compiles"] = cold["compiles"]
    return res, wall, st


def _tracked_rates(report: dict) -> dict:
    """name -> events/sec for every figure the regression gate tracks."""
    rates = {}
    for b, row in report.get("backends", {}).items():
        rates[f"backends.{b}"] = row.get("events_per_sec", 0.0)
    if "sharding" in report:
        rates["sharding"] = report["sharding"].get("events_per_sec", 0.0)
    if "roofline" in report:
        # achieved fraction of the memory roof: dimensionless, but the
        # same bigger-is-better ratio gate applies
        rates["roofline"] = report["roofline"].get("achieved_fraction", 0.0)
    if "open_loop" in report:
        rates["open_loop"] = report["open_loop"].get("events_per_sec", 0.0)
    if "leaderboard" in report:
        lb = report["leaderboard"]
        rates["leaderboard"] = lb.get("events_per_sec", 0.0)
        for alg, row in lb.get("algorithms", {}).items():
            # simulated Mops, not harness ev/s — still a per-row trajectory
            # figure the same ratio gate applies to
            rates[f"leaderboard.{alg}"] = row.get("mean_mops", 0.0)
    return rates


def _check_baseline(report: dict, path: str, tolerance: float) -> bool:
    """Diff the fresh report's events/sec against a previous run's JSON."""
    if not os.path.exists(path):
        print(f"# baseline: {path} not found — nothing to regress against",
              flush=True)
        return True
    with open(path) as f:
        base = json.load(f)
    bg, fg = base.get("grid", {}), report["grid"]
    keys = ("events_per_replica", "configs", "seeds")
    if tuple(bg.get(k) for k in keys) != tuple(fg[k] for k in keys):
        print(f"# baseline: {path} measured a different grid "
              f"({ {k: bg.get(k) for k in keys} } vs "
              f"{ {k: fg[k] for k in keys} }) — comparison skipped",
              flush=True)
        return True
    base_rates = _tracked_rates(base)
    ok = True
    for name, fresh in _tracked_rates(report).items():
        ref = base_rates.get(name)
        if not ref or ref <= 0:
            continue        # row absent in the baseline: new, not regressed
        ratio = fresh / ref
        verdict = "ok" if ratio >= 1.0 - tolerance else "REGRESSION"
        ok = ok and verdict == "ok"
        unit = ("Mops" if name.startswith("leaderboard.")
                else "of-roof" if name == "roofline" else "ev/s")
        print(f"# baseline {name}: {fresh:,.1f} vs {ref:,.1f} {unit} "
              f"({ratio:.3f}x) {verdict}", flush=True)
    if not ok:
        print(f"# perfcheck: events/sec regressed more than "
              f"{tolerance:.0%} vs {path}", flush=True)
    return ok


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backends", default="xla,pallas",
                    help="comma list of backends to measure")
    ap.add_argument("--events", type=int, default=EVENTS)
    ap.add_argument("--seeds", type=int, default=1)
    ap.add_argument("--chunk", type=int, default=None,
                    help="rows/device/dispatch for the sharded leg "
                         "(default: half a bucket, forcing 2 chunks)")
    ap.add_argument("--out", default="BENCH_events_per_sec.json")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help="previous BENCH_events_per_sec.json to diff "
                         "against; exit non-zero on an events/sec "
                         "regression beyond the tolerance")
    ap.add_argument("--regression-tolerance", type=float, default=0.10,
                    metavar="FRAC",
                    help="allowed fractional events/sec drop vs the "
                         "baseline (default 0.10)")
    ap.add_argument("--min-sharded-ratio", type=float, default=0.0,
                    metavar="FRAC",
                    help="fail if sharded ev/s falls below FRAC x the "
                         "unsharded XLA leg (default 0.0 = report only)")
    args = ap.parse_args()
    if args.baseline and not 0.0 < args.regression_tolerance < 1.0:
        ap.error(f"--regression-tolerance must be in (0, 1), got "
                 f"{args.regression_tolerance}")

    cfgs = _grid()
    n_buckets = len({batch.shape_key(c, args.events) for c in cfgs})
    total_events = len(cfgs) * args.seeds * args.events
    report = {
        "scenario": "paper-fig5",
        "grid": {"configs": len(cfgs), "seeds": args.seeds,
                 "events_per_replica": args.events,
                 "total_events": total_events, "buckets": n_buckets},
        "backends": {},
    }

    base = None     # (backend, results) of the first measured backend
    base_xla = None  # unsharded XLA results — the oracle both legs diff to
    for backend in [b.strip() for b in args.backends.split(",") if b.strip()]:
        res, wall, st = _timed_sweep(cfgs, args.seeds, args.events,
                                     backend=backend)
        eps = total_events / max(wall, 1e-9)
        report["backends"][backend] = {
            "wall_s": round(wall, 4), "events_per_sec": round(eps, 1),
            "dispatches": st["dispatches"], "compiles": st["compiles"],
            "vmem_plan": st.get("vmem_plan"),
        }
        print(f"perfcheck.{backend},{wall*1e6/len(cfgs):.1f},"
              f"{eps/1e6:.3f}Mevents/s", flush=True)
        if backend == "xla":
            base_xla = res
        if base is None:
            base = (backend, res)
        else:
            same = all(np.array_equal(a.lat_ns, b.lat_ns)
                       and np.array_equal(a.ops, b.ops)
                       for a, b in zip(base[1], res))
            report["backends"][backend]["bitwise_equal_to_" + base[0]] = same
    if base_xla is None:
        # the sharded leg below runs on xla, so its bitwise check needs an
        # unsharded xla oracle even when --backends skipped it (untimed)
        base_xla = batch.sweep(cfgs, n_seeds=args.seeds,
                               n_events=args.events, backend="xla")

    # sharded/chunked layout: one dispatch per chunk (per mesh), one compile
    # per shape key — dispatch-count evidence that oversized buckets spill
    # into fixed-size chunks instead of recompiling
    bucket_rows = max(args.seeds * len(LOCALITY), 1)
    chunk = args.chunk or max(1, -(-bucket_rows // 2))
    res_c, wall_c, st_c = _timed_sweep(cfgs, args.seeds, args.events,
                                       backend="xla", chunk=chunk)
    eq = all(
        np.array_equal(a.lat_ns, b.lat_ns) and np.array_equal(a.ops, b.ops)
        for a, b in zip(base_xla, res_c))
    report["sharding"] = {
        "chunk_rows_per_device": chunk,
        "bucket_rows": bucket_rows,
        "wall_s": round(wall_c, 4),
        "events_per_sec": round(total_events / max(wall_c, 1e-9), 1),
        "dispatches": st_c["dispatches"],
        "compiles": st_c["compiles"],
        "unsharded_dispatches_per_bucket": 1,
        "bitwise_equal_to_unsharded": bool(eq),
    }
    ratio_vs_unsharded = None
    if "xla" in report["backends"]:
        ratio_vs_unsharded = report["sharding"]["events_per_sec"] / max(
            report["backends"]["xla"]["events_per_sec"], 1e-9)
        report["sharding"]["ratio_vs_unsharded"] = round(
            ratio_vs_unsharded, 3)
    print(f"perfcheck.sharded.chunk{chunk},{wall_c*1e6/len(cfgs):.1f},"
          f"dispatches={st_c['dispatches']},compiles={st_c['compiles']},"
          f"bitwise_ok={eq},ratio="
          + (f"{ratio_vs_unsharded:.3f}" if ratio_vs_unsharded is not None
             else "n/a"), flush=True)

    # open-loop leg: the arrival-ingestion code path is a different kernel
    # trace (R > 0 adds the request lanes), so its events/sec is tracked
    # as its own trajectory row, with the simulated serving split alongside
    open_cfgs = _open_grid()
    res_o, wall_o, st_o = _timed_sweep(open_cfgs, args.seeds, args.events,
                                       backend="xla")
    open_events = len(open_cfgs) * args.seeds * args.events
    report["open_loop"] = {
        "rate_per_us": OPEN_RATE_PER_US, "requests": OPEN_REQS,
        "queue_cap": OPEN_QCAP, "wall_s": round(wall_o, 4),
        "events_per_sec": round(open_events / max(wall_o, 1e-9), 1),
        "dispatches": st_o["dispatches"], "compiles": st_o["compiles"],
        "workloads": {},
    }
    for w, br in zip(open_cfgs, res_o):
        sm = br.serving_mean()
        report["open_loop"]["workloads"][w.alg] = {
            "offered_per_us": round(sm["offered_per_us"], 3),
            "goodput_per_us": round(sm["goodput_per_us"], 3),
            "drop_rate": round(sm["drop_rate"], 4),
        }
        print(f"perfcheck.open_loop.{w.alg},"
              f"{wall_o * 1e6 / len(open_cfgs):.1f},"
              f"offered={sm['offered_per_us']:.3f}/us,"
              f"goodput={sm['goodput_per_us']:.3f}/us,"
              f"drop={sm['drop_rate']:.3f}", flush=True)

    # leaderboard leg: one sweep over all five algorithms, ranked by
    # simulated throughput — each algorithm's mean_mops is a tracked
    # trajectory row (the only leg that exercises hlock and alock-rw)
    lb_cfgs = _leaderboard_grid()
    res_l, wall_l, st_l = _timed_sweep(lb_cfgs, args.seeds, args.events,
                                       backend="xla")
    lb_events = len(lb_cfgs) * args.seeds * args.events
    report["leaderboard"] = {
        "locality": LB_LOCALITY, "read_frac": LB_READ_FRAC,
        "wall_s": round(wall_l, 4),
        "events_per_sec": round(lb_events / max(wall_l, 1e-9), 1),
        "dispatches": st_l["dispatches"], "compiles": st_l["compiles"],
        "algorithms": {},
    }
    ranked = sorted(zip(lb_cfgs, res_l), key=lambda p: -p[1].mean_mops)
    for rank, (w, br) in enumerate(ranked, 1):
        report["leaderboard"]["algorithms"][w.alg] = {
            "rank": rank,
            "mean_mops": round(br.mean_mops, 4),
            "p99_lat_ns": round(br.p99_lat_ns, 1),
        }
        print(f"perfcheck.leaderboard.r{rank}.{w.alg},"
              f"{wall_l * 1e6 / len(lb_cfgs):.1f},"
              f"{br.mean_mops:.3f}Mops,p99={br.p99_lat_ns:.0f}ns",
              flush=True)

    # roofline leg: the events/sec-per-byte ceiling for the fig5 kernel
    # shape (byte table x measured copy bandwidth) and the fraction of it
    # the fastest backend achieved — the fraction is its own tracked
    # trajectory row, robust to absolute runner-speed drift
    alg0, T0, N0, K0, _, R0 = batch.shape_key(cfgs[0], args.events)
    vp = (report["backends"].get("pallas") or {}).get("vmem_plan") or {}
    mkw = dict(T=T0, N=N0, K=K0, R=R0, hl=alg0 == "hlock",
               rw=alg0 == "alock-rw")
    if vp:
        mkw.update(tile=vp["tile"], ev_chunk=vp["ev_chunk"],
                   lat_samples=vp["lat_samples"],
                   repr32=vp["representation"] == "i32pair")
    m = roofline.model(**mkw)
    bw = roofline.measure_bandwidth()
    roof = roofline.roof_events_per_sec(bw, m)
    best = max((row["events_per_sec"]
                for row in report["backends"].values()), default=0.0)
    report["roofline"] = {
        "bandwidth_bytes_per_s": round(bw, 1),
        "bytes_per_event": m["bytes_per_event"],
        "roof_events_per_sec": round(roof, 1),
        "best_backend_events_per_sec": best,
        "achieved_fraction": round(best / max(roof, 1e-9), 5),
    }
    print(f"perfcheck.roofline,{m['bytes_per_event']:.1f},"
          f"roof={roof / 1e6:.1f}Mev/s,"
          f"achieved={report['roofline']['achieved_fraction']:.4f}",
          flush=True)

    bk = report["backends"]
    if "xla" in bk and "pallas" in bk:
        report["pallas_over_xla"] = round(
            bk["pallas"]["events_per_sec"] / max(bk["xla"]["events_per_sec"],
                                                 1e-9), 3)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"# wrote {args.out}", flush=True)

    failed = False
    if (args.min_sharded_ratio > 0.0 and ratio_vs_unsharded is not None
            and ratio_vs_unsharded < args.min_sharded_ratio):
        print(f"# perfcheck: sharded/unsharded ratio "
              f"{ratio_vs_unsharded:.3f} below --min-sharded-ratio "
              f"{args.min_sharded_ratio}", flush=True)
        failed = True
    if args.baseline and not _check_baseline(report, args.baseline,
                                             args.regression_tolerance):
        failed = True
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Benchmark entry point — paper sections and registered scenarios.

Usage: PYTHONPATH=src python -m benchmarks.run [section ...] [--seeds N]
           [--backend auto|xla|pallas] [--devices N] [--chunk R] [--zipf S]
           [--scenario NAME ... | --scenario all] [--list-scenarios]
           [--scenario-out FILE]
Prints ``name,us_per_call,derived`` CSV rows.

Sections reproduce the paper's figures; ``--scenario NAME`` runs a named
workload program from the registry (``repro.experiments.registry``) — the
same single entry point ``perfcheck.py`` and CI use. ``--scenario all``
runs every registered scenario; ``--scenario-out FILE`` additionally
writes the scenario rows as JSON with the scenario name recorded per row.

--seeds N runs every simulator workload with N independent seeds (batched
in one vmapped dispatch per shape bucket — no extra compiles) and turns
the derived columns into mean±ci95. --backend/--devices/--chunk build the
immutable ``ExecOptions`` value threaded explicitly into every section and
scenario (no process-wide execution state). --zipf skews the within-node
lock choice for sections that support it (fig5). Kernel/roofline sections
ignore the simulator flags. ``benchmarks.perfcheck`` records events/sec
per backend.
"""
import argparse
import inspect
import json
import time

from benchmarks import (common, fig1_loopback, fig4_budget, fig5_throughput,
                        fig6_latency, microbench, roofline)
from repro.experiments import ExecOptions, run_scenario, scenario_names

SECTIONS = {
    "fig1": fig1_loopback.main,
    "fig4": fig4_budget.main,
    "fig5": fig5_throughput.main,
    "fig6": fig6_latency.main,
    "micro": microbench.main,
    "roofline": roofline.main,
}


def _emit_scenario(name: str, n_seeds: int, options: ExecOptions) -> list:
    t0 = time.time()
    rows = run_scenario(name, n_seeds=n_seeds, n_events=common.EVENTS,
                        options=options)
    wall = time.time() - t0
    for r in rows:
        common.emit(f"scenario.{name}.{r['name']}", r["us_per_call"],
                    r["derived"])
        r["scenario"] = name
    print(f"# scenario {name} done in {wall:.1f}s", flush=True)
    # one simulator replica set per row carrying mean_mops; scenarios that
    # never touch the simulator (coord-stress) report wall time only
    n_sim = sum(1 for r in rows if "mean_mops" in r)
    summary = {"scenario": name, "name": f"{name}.wall",
               "wall_s": round(wall, 3), "simulated_workloads": n_sim,
               "events_per_replica": common.EVENTS, "seeds": n_seeds}
    if n_sim:
        total_events = common.EVENTS * n_seeds * n_sim
        summary["total_events"] = total_events
        summary["events_per_sec"] = round(total_events / max(wall, 1e-9), 1)
    return rows + [summary]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("sections", nargs="*", metavar="section",
                    help=f"sections to run (default: all of "
                         f"{', '.join(SECTIONS)})")
    ap.add_argument("--seeds", type=int, default=1,
                    help="independent seeds per simulator workload")
    ap.add_argument("--backend", choices=("auto", "xla", "pallas"),
                    default=None, help="simulator execution backend")
    ap.add_argument("--devices", type=int, default=None,
                    help="shard sweep buckets over this many JAX devices")
    ap.add_argument("--chunk", type=int, default=None,
                    help="rows per device per dispatch (fixed-size chunks)")
    ap.add_argument("--zipf", type=float, default=0.0,
                    help="Zipf skew of within-node lock targets (fig5)")
    ap.add_argument("--scenario", action="append", default=[],
                    metavar="NAME",
                    help="run a registered scenario ('all' = every one); "
                         "repeatable; replaces the default section list")
    ap.add_argument("--list-scenarios", action="store_true",
                    help="print registered scenario names and exit")
    ap.add_argument("--scenario-out", default=None, metavar="FILE",
                    help="write scenario rows as JSON (scenario name "
                         "recorded per row)")
    args = ap.parse_args()
    if args.list_scenarios:
        for name in scenario_names():
            print(name)
        return
    if args.seeds < 1:
        ap.error(f"--seeds must be >= 1, got {args.seeds}")
    try:
        options = ExecOptions.from_env(backend=args.backend,
                                       devices=args.devices,
                                       chunk=args.chunk)
    except ValueError as e:
        ap.error(str(e))

    scen = args.scenario
    if "all" in scen:
        scen = scenario_names()
    unknown = [s for s in scen if s not in scenario_names()]
    if unknown:
        ap.error(f"unknown scenario(s) {unknown}; pick from "
                 f"{scenario_names()}")
    unknown = [s for s in args.sections if s not in SECTIONS]
    if unknown:
        ap.error(f"unknown section(s) {unknown}; pick from "
                 f"{list(SECTIONS)}")
    if args.scenario_out and not scen:
        ap.error("--scenario-out requires --scenario")

    print("name,us_per_call,derived")
    all_rows = []
    for name in scen:
        all_rows += _emit_scenario(name, args.seeds, options)
    if args.scenario_out and scen:
        with open(args.scenario_out, "w") as f:
            json.dump(all_rows, f, indent=2, sort_keys=True, default=str)
        print(f"# wrote {args.scenario_out}", flush=True)

    which = args.sections or ([] if scen else list(SECTIONS))
    for name in which:
        fn = SECTIONS[name]
        params = inspect.signature(fn).parameters
        kwargs = {}
        if "n_seeds" in params:
            kwargs["n_seeds"] = args.seeds
        if "options" in params:
            kwargs["options"] = options
        if "zipf" in params and args.zipf:
            kwargs["zipf"] = args.zipf
        t0 = time.time()
        fn(**kwargs)
        print(f"# section {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()

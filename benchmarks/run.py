"""Benchmark entry point — one section per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [section ...] [--seeds N]
Prints ``name,us_per_call,derived`` CSV rows.

--seeds N runs every simulator config with N independent seeds (batched in
one vmapped dispatch per shape bucket — no extra compiles) and turns the
derived columns into mean±ci95. Kernel/roofline sections ignore the flag.
"""
import argparse
import inspect
import time

from benchmarks import (fig1_loopback, fig4_budget, fig5_throughput,
                        fig6_latency, microbench, roofline)

SECTIONS = {
    "fig1": fig1_loopback.main,
    "fig4": fig4_budget.main,
    "fig5": fig5_throughput.main,
    "fig6": fig6_latency.main,
    "micro": microbench.main,
    "roofline": roofline.main,
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("sections", nargs="*", metavar="section",
                    help=f"sections to run (default: all of "
                         f"{', '.join(SECTIONS)})")
    ap.add_argument("--seeds", type=int, default=1,
                    help="independent seeds per simulator config")
    args = ap.parse_args()
    if args.seeds < 1:
        ap.error(f"--seeds must be >= 1, got {args.seeds}")
    unknown = [s for s in args.sections if s not in SECTIONS]
    if unknown:
        ap.error(f"unknown section(s) {unknown}; pick from "
                 f"{list(SECTIONS)}")
    which = args.sections or list(SECTIONS)
    print("name,us_per_call,derived")
    for name in which:
        fn = SECTIONS[name]
        kwargs = {}
        if "n_seeds" in inspect.signature(fn).parameters:
            kwargs["n_seeds"] = args.seeds
        t0 = time.time()
        fn(**kwargs)
        print(f"# section {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()

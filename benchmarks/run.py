"""Benchmark entry point — one section per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [section ...]
Prints ``name,us_per_call,derived`` CSV rows.
"""
import sys
import time

from benchmarks import (fig1_loopback, fig4_budget, fig5_throughput,
                        fig6_latency, microbench, roofline)

SECTIONS = {
    "fig1": fig1_loopback.main,
    "fig4": fig4_budget.main,
    "fig5": fig5_throughput.main,
    "fig6": fig6_latency.main,
    "micro": microbench.main,
    "roofline": roofline.main,
}


def main() -> None:
    which = sys.argv[1:] or list(SECTIONS)
    print("name,us_per_call,derived")
    for name in which:
        t0 = time.time()
        SECTIONS[name]()
        print(f"# section {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()

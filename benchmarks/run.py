"""Benchmark entry point — paper sections and registered scenarios.

Usage: PYTHONPATH=src python -m benchmarks.run [section ...] [--seeds N]
           [--backend auto|xla|pallas] [--devices N] [--chunk R] [--zipf S]
           [--scenario NAME ... | --scenario all] [--list-scenarios]
           [--scenario-out FILE] [--check-slo] [--slo-p99-ns NS]
           [--slo-min-eps RATE]
Prints ``name,us_per_call,derived`` CSV rows.

Sections reproduce the paper's figures; ``--scenario NAME`` runs a named
workload program from the registry (``repro.experiments.registry``) — the
same single entry point ``perfcheck.py`` and CI use. ``--scenario all``
runs every registered scenario; ``--scenario-out FILE`` additionally
writes the scenario rows as JSON with the scenario name recorded per row.
Running more than one scenario appends a cross-algorithm leaderboard:
per scenario, every algorithm that ran is ranked by its best workload
row's throughput (simulated p99 alongside) as
``leaderboard.<scenario>.r<rank>.<alg>`` rows.

--seeds N runs every simulator workload with N independent seeds (batched
in one vmapped dispatch per shape bucket — no extra compiles) and turns
the derived columns into mean±ci95. --backend/--devices/--chunk build the
immutable ``ExecOptions`` value threaded explicitly into every section and
scenario (no process-wide execution state). --zipf skews the within-node
lock choice for sections that support it (fig5). Kernel/roofline sections
ignore the simulator flags. ``benchmarks.perfcheck`` records events/sec
per backend.

--check-slo evaluates each run scenario's registered SLO
(``repro.experiments.Slo``: simulated p99 latency ceiling + wall-clock
events/sec floor) against its result rows and exits non-zero on any
violation — the CI scenarios leg runs under this gate. --slo-p99-ns /
--slo-min-eps override that bound for every checked scenario (merged
onto the registered Slo — the other bound stays enforced — and implying
--check-slo); that is how the exit-code tests deliberately violate an
SLO.
"""
import argparse
import inspect
import json
import sys
import time

from benchmarks import (common, fig1_loopback, fig4_budget, fig5_throughput,
                        fig6_latency, microbench, roofline, serving_curves)
from repro.core import batch
from repro.experiments import (ExecOptions, Slo, check_slo, get_scenario,
                               run_scenario, scenario_names)

SECTIONS = {
    "fig1": fig1_loopback.main,
    "fig4": fig4_budget.main,
    "fig5": fig5_throughput.main,
    "fig6": fig6_latency.main,
    "micro": microbench.main,
    "roofline": roofline.main,
    "serving": serving_curves.main,
}


def _emit_scenario(name: str, n_seeds: int, options: ExecOptions) -> list:
    batch.reset_exec_stats()
    t0 = time.time()
    rows = run_scenario(name, n_seeds=n_seeds, n_events=common.EVENTS,
                        options=options)
    wall = time.time() - t0
    for r in rows:
        common.emit(f"scenario.{name}.{r['name']}", r["us_per_call"],
                    r["derived"])
        r["scenario"] = name
    print(f"# scenario {name} done in {wall:.1f}s", flush=True)
    # one simulator replica set per row carrying mean_mops; scenarios that
    # never touch the simulator (coord-stress) report wall time only
    n_sim = sum(1 for r in rows if "mean_mops" in r)
    summary = {"scenario": name, "name": f"{name}.wall",
               "wall_s": round(wall, 3), "simulated_workloads": n_sim,
               "events_per_replica": common.EVENTS, "seeds": n_seeds}
    if n_sim:
        total_events = common.EVENTS * n_seeds * n_sim
        summary["total_events"] = total_events
        summary["events_per_sec"] = round(total_events / max(wall, 1e-9), 1)
    # pallas runs leave the event-loop kernel's VMEM plan behind (tile
    # chosen vs requested, bytes, clock representation) — record it so the
    # JSON artifact shows whether the planner had to shrink the tile
    vp = batch.exec_stats().get("vmem_plan")
    if vp is not None:
        summary["vmem_plan"] = vp
        print(f"# scenario {name} vmem plan: tile {vp['requested_tile']}"
              f"->{vp['tile']}, {vp['total_bytes']:,}B "
              f"({vp['representation']})", flush=True)
    return rows + [summary]


def _leaderboard(all_rows: list) -> list:
    """Cross-algorithm leaderboard over every scenario that just ran.

    Per scenario, each algorithm is represented by its best-throughput
    workload row (rows carry ``alg`` since the registry labels them) and
    ranked by ``mean_mops``; the row's simulated p99 rides along so the
    table reads as throughput *and* tail latency per algorithm. Emitted
    as ``leaderboard.<scenario>.r<rank>.<alg>`` CSV rows and appended to
    the JSON artifact under scenario name ``leaderboard``.
    """
    best: dict = {}
    for r in all_rows:
        alg = r.get("alg")
        if alg is None or "mean_mops" not in r:
            continue
        key = (r["scenario"], alg)
        if key not in best or r["mean_mops"] > best[key]["mean_mops"]:
            best[key] = r
    rows = []
    for scen in sorted({s for s, _ in best}):
        ranked = sorted((kv for kv in best.items() if kv[0][0] == scen),
                        key=lambda kv: -kv[1]["mean_mops"])
        for rank, ((_, alg), r) in enumerate(ranked, 1):
            name = f"leaderboard.{scen}.r{rank}.{alg}"
            derived = (f"{r['mean_mops']:.3f}Mops "
                       f"p99={r['p99_lat_ns']:.0f}ns ({r['name']})")
            common.emit(name, 0.0, derived)
            rows.append({"scenario": "leaderboard", "name": name,
                         "us_per_call": 0.0, "derived": derived,
                         "rank": rank, "alg": alg,
                         "ranked_scenario": scen,
                         "best_row": r["name"],
                         "best_mean_mops": r["mean_mops"],
                         "best_p99_lat_ns": r["p99_lat_ns"]})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("sections", nargs="*", metavar="section",
                    help=f"sections to run (default: all of "
                         f"{', '.join(SECTIONS)})")
    ap.add_argument("--seeds", type=int, default=1,
                    help="independent seeds per simulator workload")
    ap.add_argument("--backend", choices=("auto", "xla", "pallas"),
                    default=None, help="simulator execution backend")
    ap.add_argument("--devices", type=int, default=None,
                    help="shard sweep buckets over this many JAX devices")
    ap.add_argument("--chunk", type=int, default=None,
                    help="rows per device per dispatch (fixed-size chunks)")
    ap.add_argument("--zipf", type=float, default=0.0,
                    help="Zipf skew of within-node lock targets (fig5)")
    ap.add_argument("--scenario", action="append", default=[],
                    metavar="NAME",
                    help="run a registered scenario ('all' = every one); "
                         "repeatable; replaces the default section list")
    ap.add_argument("--list-scenarios", action="store_true",
                    help="print registered scenario names and exit")
    ap.add_argument("--scenario-out", default=None, metavar="FILE",
                    help="write scenario rows as JSON (scenario name "
                         "recorded per row)")
    ap.add_argument("--check-slo", action="store_true",
                    help="evaluate each scenario's registered SLO and "
                         "exit non-zero on violation")
    ap.add_argument("--slo-p99-ns", type=float, default=None, metavar="NS",
                    help="override the p99 latency ceiling (ns) for every "
                         "checked scenario")
    ap.add_argument("--slo-min-eps", type=float, default=None,
                    metavar="RATE",
                    help="override the wall-clock events/sec floor for "
                         "every checked scenario")
    args = ap.parse_args()
    if args.list_scenarios:
        for name in scenario_names():
            print(name)
        return
    if args.seeds < 1:
        ap.error(f"--seeds must be >= 1, got {args.seeds}")
    try:
        options = ExecOptions.from_env(backend=args.backend,
                                       devices=args.devices,
                                       chunk=args.chunk)
    except ValueError as e:
        ap.error(str(e))

    scen = args.scenario
    if "all" in scen:
        scen = scenario_names()
    unknown = [s for s in scen if s not in scenario_names()]
    if unknown:
        ap.error(f"unknown scenario(s) {unknown}; pick from "
                 f"{scenario_names()}")
    unknown = [s for s in args.sections if s not in SECTIONS]
    if unknown:
        ap.error(f"unknown section(s) {unknown}; pick from "
                 f"{list(SECTIONS)}")
    if args.scenario_out and not scen:
        ap.error("--scenario-out requires --scenario")
    slo_override = (args.slo_p99_ns is not None
                    or args.slo_min_eps is not None)
    if slo_override:
        args.check_slo = True        # an override implies the gate
        try:
            # fail fast on bad bounds, before any scenario runs
            Slo(p99_ns=args.slo_p99_ns,
                min_events_per_sec=args.slo_min_eps)
        except ValueError as e:
            ap.error(str(e))
    if args.check_slo and not scen:
        ap.error("--check-slo / --slo-* require --scenario")

    print("name,us_per_call,derived")
    all_rows = []
    for name in scen:
        all_rows += _emit_scenario(name, args.seeds, options)
    if len(scen) > 1:
        all_rows += _leaderboard(all_rows)
    if args.scenario_out and scen:
        with open(args.scenario_out, "w") as f:
            json.dump(all_rows, f, indent=2, sort_keys=True, default=str)
        print(f"# wrote {args.scenario_out}", flush=True)

    if args.check_slo:
        failed = False
        for name in scen:
            slo = get_scenario(name).slo
            if slo_override:
                # merge onto the registered bounds: an overridden field
                # wins, the other keeps its registered value (overriding
                # one bound must not silently disable the other)
                slo = Slo(
                    p99_ns=(args.slo_p99_ns if args.slo_p99_ns is not None
                            else slo.p99_ns if slo else None),
                    min_events_per_sec=(
                        args.slo_min_eps if args.slo_min_eps is not None
                        else slo.min_events_per_sec if slo else None),
                    per_label=slo.per_label if slo else ())
            if slo is None:
                print(f"# slo {name}: none registered, skipped",
                      flush=True)
                continue
            rep = check_slo(slo,
                            [r for r in all_rows if r["scenario"] == name])
            verdict = "PASS" if rep.ok else "FAIL"
            print(f"# slo {name}: {verdict} ({rep.checked} row(s) checked)",
                  flush=True)
            for v in rep.violations:
                print(f"# slo {name}: VIOLATION {v}", flush=True)
            failed = failed or not rep.ok
        if failed:
            sys.exit(1)

    which = args.sections or ([] if scen else list(SECTIONS))
    for name in which:
        fn = SECTIONS[name]
        params = inspect.signature(fn).parameters
        kwargs = {}
        if "n_seeds" in params:
            kwargs["n_seeds"] = args.seeds
        if "options" in params:
            kwargs["options"] = options
        if "zipf" in params and args.zipf:
            kwargs["zipf"] = args.zipf
        t0 = time.time()
        fn(**kwargs)
        print(f"# section {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()

"""Benchmark entry point — one section per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [section ...] [--seeds N]
           [--backend auto|xla|pallas] [--devices N] [--chunk R] [--zipf S]
Prints ``name,us_per_call,derived`` CSV rows.

--seeds N runs every simulator config with N independent seeds (batched in
one vmapped dispatch per shape bucket — no extra compiles) and turns the
derived columns into mean±ci95. --backend selects the per-replica engine
(XLA fori_loop vs the Pallas event-loop kernel); --devices/--chunk shard
each bucket's flattened (config x seed) axis across devices in fixed-size
chunks (see core/batch.py). --zipf skews the within-node lock choice for
sections that support it (fig5). Kernel/roofline sections ignore the
simulator flags. ``benchmarks.perfcheck`` records events/sec per backend.
"""
import argparse
import inspect
import time

from benchmarks import (common, fig1_loopback, fig4_budget, fig5_throughput,
                        fig6_latency, microbench, roofline)

SECTIONS = {
    "fig1": fig1_loopback.main,
    "fig4": fig4_budget.main,
    "fig5": fig5_throughput.main,
    "fig6": fig6_latency.main,
    "micro": microbench.main,
    "roofline": roofline.main,
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("sections", nargs="*", metavar="section",
                    help=f"sections to run (default: all of "
                         f"{', '.join(SECTIONS)})")
    ap.add_argument("--seeds", type=int, default=1,
                    help="independent seeds per simulator config")
    ap.add_argument("--backend", choices=("auto", "xla", "pallas"),
                    default=None, help="simulator execution backend")
    ap.add_argument("--devices", type=int, default=None,
                    help="shard sweep buckets over this many JAX devices")
    ap.add_argument("--chunk", type=int, default=None,
                    help="rows per device per dispatch (fixed-size chunks)")
    ap.add_argument("--zipf", type=float, default=0.0,
                    help="Zipf skew of within-node lock targets (fig5)")
    args = ap.parse_args()
    if args.seeds < 1:
        ap.error(f"--seeds must be >= 1, got {args.seeds}")
    if args.devices is not None and args.devices < 1:
        ap.error(f"--devices must be >= 1, got {args.devices}")
    if args.chunk is not None and args.chunk < 1:
        ap.error(f"--chunk must be >= 1, got {args.chunk}")
    common.set_exec_options(backend=args.backend, devices=args.devices,
                            chunk=args.chunk)
    unknown = [s for s in args.sections if s not in SECTIONS]
    if unknown:
        ap.error(f"unknown section(s) {unknown}; pick from "
                 f"{list(SECTIONS)}")
    which = args.sections or list(SECTIONS)
    print("name,us_per_call,derived")
    for name in which:
        fn = SECTIONS[name]
        params = inspect.signature(fn).parameters
        kwargs = {}
        if "n_seeds" in params:
            kwargs["n_seeds"] = args.seeds
        if "zipf" in params and args.zipf:
            kwargs["zipf"] = args.zipf
        t0 = time.time()
        fn(**kwargs)
        print(f"# section {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()

"""Serving curves: goodput and p99 sojourn vs offered load, with knees.

Runs the registry's ``open-loop-ramp`` scenario — a Poisson arrival
stream at each rate of the ramp per algorithm, bounded wait queue with
tail drop — and prints the serving-curve table the paper's closed-loop
figures cannot show: offered vs achieved request rate, drop rate, and
p99 *sojourn* (arrival -> departure, queueing included) next to the p99
acquire latency the closed-loop benches report. Below each algorithm's
saturation knee the two goodput columns track; above it the queue
overflows and the drop column absorbs the difference. The knee lines at
the bottom are ``repro.traffic.metrics.detect_knee`` over the measured
curve — ALock's local-handoff capacity sits several times above the
loopback designs, which is the serving-path restatement of the paper's
throughput asymmetry.

Usage: PYTHONPATH=src python -m benchmarks.serving_curves [--seeds N]
           [--events N] [--backend auto|xla|pallas] [--devices N]
           [--chunk R]
Also runnable as the ``serving`` section of ``benchmarks.run``.
"""
from __future__ import annotations

import argparse

from benchmarks.common import EVENTS
from repro.experiments import ExecOptions, run_scenario


def _fmt_us(ns: float) -> str:
    return f"{ns / 1e3:.2f}" if ns == ns else "nan"      # NaN-safe


def main(n_seeds: int = 1, options: ExecOptions | None = None,
         events: int | None = None) -> None:
    options = options or ExecOptions.from_env()
    rows = run_scenario("open-loop-ramp", n_seeds=n_seeds,
                        n_events=events or EVENTS, options=options)
    by_name = {r["name"]: r for r in rows}
    print(f"{'workload':<18}{'offered/us':>11}{'goodput/us':>11}"
          f"{'drop':>7}{'p99.soj.us':>12}{'p99.acq.us':>12}")
    for r in rows:
        if not r["name"].endswith(".serving"):
            continue
        lbl = r["name"][:-len(".serving")]
        acq = by_name.get(lbl, {}).get("p99_lat_ns", float("nan"))
        print(f"{lbl:<18}{r['offered_per_us']:>11.3f}"
              f"{r['goodput_per_us']:>11.3f}{r['drop_rate']:>7.3f}"
              f"{_fmt_us(r['p99_sojourn_ns']):>12}{_fmt_us(acq):>12}")
    for r in rows:
        if r["name"].endswith(".knee"):
            print(f"# {r['name']}: {r['derived']}", flush=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seeds", type=int, default=1)
    ap.add_argument("--events", type=int, default=EVENTS)
    ap.add_argument("--backend", choices=("auto", "xla", "pallas"),
                    default=None)
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--chunk", type=int, default=None)
    args = ap.parse_args()
    main(n_seeds=args.seeds,
         options=ExecOptions.from_env(backend=args.backend,
                                      devices=args.devices,
                                      chunk=args.chunk),
         events=args.events)

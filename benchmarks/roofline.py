"""Events/sec-per-byte roofline for the event-loop kernel.

Replaces the seed's dry-run roofline, which globbed
``artifacts/dryrun/*.json`` left behind by the deleted launch stack and
therefore always printed ``roofline.missing``. The event-loop simulator
is memory-bound on its streamed draw inputs: per replica-event the
kernel reads u1 (f32) + r2 + r3 (i32) = 12 B (16 B with the alock-rw
coin stream u4), and each grid step additionally moves its
VMEM-resident working set — workload rows, state scratch, outputs —
once. The model:

  bytes/event = streamed B/event + resident_bytes / (tile * ev_chunk)
  roof ev/s   = measured copy bandwidth * (1 / bytes/event)

Resident and streamed bytes come straight from ``vmem.buffer_table``
(the byte table the analysis V001 rule diffs against the traced
kernel), with the pipeline double-buffer factor divided back out of the
streamed entries — the roofline counts traffic, not residency. Host
bandwidth is *measured* (a large ``np`` copy, read + write traffic), so
the roof moves with the machine instead of trusting a hard-coded
constant. ``benchmarks/perfcheck.py`` reuses :func:`model` and
:func:`roof_events_per_sec` to record each PR's achieved fraction as a
tracked trajectory row.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.sim import LAT_SAMPLES
from repro.kernels.event_loop import vmem
from repro.kernels.event_loop.ops import DEFAULT_EV_CHUNK, DEFAULT_TILE


def measure_bandwidth(mib: int = 64, iters: int = 3) -> float:
    """Best-of-``iters`` host copy bandwidth in bytes/sec.

    Copy traffic is read + write, hence the factor 2; best-of keeps the
    figure stable against scheduler noise on shared CI runners.
    """
    a = np.zeros(mib << 20, np.uint8)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        b = a.copy()
        best = min(best, time.perf_counter() - t0)
        del b
    return 2.0 * a.nbytes / max(best, 1e-9)


def model(*, tile: int = DEFAULT_TILE, ev_chunk: int = DEFAULT_EV_CHUNK,
          T: int = 16, N: int = 4, K: int = 16, P: int = 1,
          lat_samples: int = LAT_SAMPLES, repr32: bool = True,
          R: int = 0, hl: bool = False, rw: bool = False) -> dict:
    """Bytes/event and events/byte for one kernel configuration.

    Everything is derived from :func:`vmem.buffer_table`, so the model
    can never drift from the byte table the analysis lint checks.
    """
    tbl = vmem.buffer_table(tile, ev_chunk, T, N, K, P, lat_samples,
                            repr32, R=R, hl=hl, rw=rw)
    streamed = sum(b for n, (_, b) in tbl.items()
                   if n in vmem.STREAMED_INPUTS)
    total = sum(b for _, b in tbl.values())
    resident = total - streamed
    per_step_events = tile * ev_chunk            # replica-events/grid step
    stream_per_event = streamed / vmem.PIPELINE_FACTOR / per_step_events
    bytes_per_event = stream_per_event + resident / per_step_events
    return {
        "tile": tile, "ev_chunk": ev_chunk,
        "streamed_bytes_per_event": round(stream_per_event, 3),
        "resident_bytes": resident,
        "bytes_per_event": round(bytes_per_event, 3),
        "events_per_byte": 1.0 / bytes_per_event,
    }


def roof_events_per_sec(bandwidth_bytes_per_s: float, m: dict) -> float:
    """Replica-events/sec ceiling implied by the memory roof."""
    return bandwidth_bytes_per_s * m["events_per_byte"]


#: the rows ``main`` prints: the Fig.5 closed-loop shape, the alock-rw
#: variant (wider stream: the u4 coin), and the open-loop shape (request
#: lanes join the resident set)
CONFIGS = (
    ("fig5", {}),
    ("alock-rw", {"rw": True}),
    ("open-loop", {"R": 256}),
)


def main() -> None:
    bw = measure_bandwidth()
    emit("roofline.bandwidth", 0.0, f"{bw / 2**30:.2f}GiB/s(copy)")
    for name, kw in CONFIGS:
        m = model(**kw)
        roof = roof_events_per_sec(bw, m)
        emit(f"roofline.{name}", m["bytes_per_event"],
             f"roof={roof / 1e6:.1f}Mev/s,"
             f"stream={m['streamed_bytes_per_event']:.0f}B/ev,"
             f"resident={m['resident_bytes'] / 1024:.0f}KiB"
             f"@tile{m['tile']}x{m['ev_chunk']}")


if __name__ == "__main__":
    main()

"""Roofline table from the dry-run artifacts (artifacts/dryrun/*.json).

Prints one row per (arch x shape x mesh): the three roofline terms in
seconds, the dominant term, and MODEL_FLOPS/HLO_FLOPs. See EXPERIMENTS.md
§Roofline for the narrative analysis.
"""
import glob
import json
import os

from benchmarks.common import emit

ART = os.environ.get("DRYRUN_ART", "artifacts/dryrun")


def rows(mesh_filter=None):
    out = []
    for f in sorted(glob.glob(os.path.join(ART, "*.json"))):
        r = json.load(open(f))
        if r.get("status") != "ok":
            continue
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        r.setdefault("variant", "opt" if "__opt" in f else "baseline")
        out.append(r)
    return out


def main() -> None:
    rs = rows()
    if not rs:
        emit("roofline.missing", 0.0,
             "no artifacts/dryrun/*.json (the dry-run generator left with "
             "the legacy launch stack)")
        return
    for r in rs:
        t = r["roofline"]
        dom_s = max(t["compute_s"], t["memory_s"], t["collective_link_s"])
        var = "." + r["variant"] if r.get("variant", "baseline") != \
            "baseline" else ""
        emit(
            f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}{var}",
            dom_s * 1e6,
            f"compute={t['compute_s']:.2e}s,mem={t['memory_s']:.2e}s,"
            f"coll={t['collective_s']:.2e}s,coll_link={t['collective_link_s']:.2e}s,"
            f"dom={t['dominant']},useful={r['useful_flops_ratio']:.3f}")


if __name__ == "__main__":
    main()

"""Fig. 5: throughput grid — nodes x contention x locality, 3 algorithms.

Trends validated against the paper:
  - 100% locality: ALock >> both competitors at every contention level;
  - high contention (20 locks): spinlock/MCS overwhelmed, ALock passes
    the lock and keeps scaling;
  - low contention (1000 locks): the gap narrows but ALock still leads at
    high locality.
"""
from benchmarks.common import emit, run, us_per_op

GRID_NODES = (5, 10, 20)
LOCKS = (20, 100, 1000)
LOCALITY = (0.85, 0.95, 1.0)
TPN = 8


def main() -> None:
    for nodes in GRID_NODES:
        for locks in LOCKS:
            for loc in LOCALITY:
                best = {}
                for alg in ("alock", "spinlock", "mcs"):
                    r = run(alg, nodes, TPN, locks, loc)
                    best[alg] = r.throughput_mops
                    emit(f"fig5.{alg}.n{nodes}.k{locks}.loc{int(loc*100)}",
                         us_per_op(r), f"{r.throughput_mops:.3f}Mops")
                emit(f"fig5.gap.n{nodes}.k{locks}.loc{int(loc*100)}", 0.0,
                     f"alock_over_spin={best['alock']/max(best['spinlock'],1e-9):.2f}x,"
                     f"alock_over_mcs={best['alock']/max(best['mcs'],1e-9):.2f}x")
    # thread scaling at the paper's largest config
    for tpn in (2, 4, 8, 12):
        r = run("alock", 20, tpn, 20, 0.95)
        s = run("spinlock", 20, tpn, 20, 0.95)
        emit(f"fig5.scaling.t{tpn}.n20.k20", us_per_op(r),
             f"alock={r.throughput_mops:.3f}Mops,spin={s.throughput_mops:.3f}Mops")


if __name__ == "__main__":
    main()

"""Fig. 5: throughput grid — nodes x contention x locality, 3 algorithms.

Trends validated against the paper:
  - 100% locality: ALock >> both competitors at every contention level;
  - high contention (20 locks): spinlock/MCS overwhelmed, ALock passes
    the lock and keeps scaling;
  - low contention (1000 locks): the gap narrows but ALock still leads at
    high locality.

The whole grid (plus the thread-scaling strip) is one Experiment:
per-(alg, T, N, K) shape bucket it compiles once and evaluates every
locality x contention x seed point in a single vmapped dispatch. Rows
report mean±ci95 throughput across ``n_seeds`` replicas.

``--zipf S`` (or ``main(zipf=S)``) skews every workload's within-node lock
choice with a Zipf(S) draw — hot-key contention on top of the locality
grid. The CDF rides the traced batch axis, so a skewed grid costs no extra
compiles (row names gain a ``.zipfS`` suffix).
"""
from benchmarks.common import emit, experiment, mops, us_per_op, wl
from repro.experiments import ExecOptions

GRID_NODES = (5, 10, 20)
LOCKS = (20, 100, 1000)
LOCALITY = (0.85, 0.95, 1.0)
TPN = 8
ALGS = ("alock", "spinlock", "mcs")
SCALING_TPN = (2, 4, 8, 12)


def main(n_seeds: int = 1, zipf: float = 0.0,
         options: ExecOptions | None = None) -> None:
    sfx = f".zipf{zipf:g}" if zipf else ""
    grid = [(n, k, l) for n in GRID_NODES for k in LOCKS for l in LOCALITY]
    exp = experiment("fig5", n_seeds=n_seeds, options=options)
    for (n, k, l) in grid:
        for alg in ALGS:
            exp.add(wl(alg, n, TPN, k, l, zipf=zipf),
                    label=f"{alg}.n{n}.k{k}.loc{int(l * 100)}")
    # thread scaling at the paper's largest config rides the same sweep
    for tpn in SCALING_TPN:
        for alg in ("alock", "spinlock"):
            exp.add(wl(alg, 20, tpn, 20, 0.95, zipf=zipf),
                    label=f"{alg}.scale.t{tpn}")
    res = exp.run()

    for n, k, l in grid:
        best = {}
        for alg in ALGS:
            br = res[f"{alg}.n{n}.k{k}.loc{int(l * 100)}"]
            best[alg] = br.mean_mops
            emit(f"fig5.{alg}.n{n}.k{k}.loc{int(l*100)}{sfx}",
                 us_per_op(br), mops(br))
        emit(f"fig5.gap.n{n}.k{k}.loc{int(l*100)}{sfx}", 0.0,
             f"alock_over_spin={best['alock']/max(best['spinlock'],1e-9):.2f}x,"
             f"alock_over_mcs={best['alock']/max(best['mcs'],1e-9):.2f}x")
    for tpn in SCALING_TPN:
        a = res[f"alock.scale.t{tpn}"]
        s = res[f"spinlock.scale.t{tpn}"]
        emit(f"fig5.scaling.t{tpn}.n20.k20{sfx}", us_per_op(a),
             f"alock={mops(a)},spin={mops(s)}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seeds", type=int, default=1)
    ap.add_argument("--zipf", type=float, default=0.0,
                    help="Zipf skew of within-node lock targets")
    a = ap.parse_args()
    main(n_seeds=a.seeds, zipf=a.zipf)

"""Fig. 1: RDMA spinlock with 1k locks on 1 node — loopback saturation.

Paper claim: throughput peaks at a few threads, then declines as loopback
traffic drains PCIe bandwidth. ALock (no loopback) keeps scaling.
"""
from benchmarks.common import emit, run, us_per_op


def main() -> None:
    peak = 0.0
    last = None
    for tpn in (1, 2, 4, 8, 12, 16):
        r = run("spinlock", 1, tpn, 1000, 1.0)
        emit(f"fig1.spinlock.1node.t{tpn}", us_per_op(r),
             f"{r.throughput_mops:.3f}Mops")
        peak = max(peak, r.throughput_mops)
        last = r.throughput_mops
        a = run("alock", 1, tpn, 1000, 1.0)
        emit(f"fig1.alock.1node.t{tpn}", us_per_op(a),
             f"{a.throughput_mops:.3f}Mops")
    emit("fig1.spinlock.collapse_ratio", 0.0,
         f"{peak / max(last, 1e-9):.2f}x_peak_over_t16")


if __name__ == "__main__":
    main()

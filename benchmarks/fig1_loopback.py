"""Fig. 1: RDMA spinlock with 1k locks on 1 node — loopback saturation.

Paper claim: throughput peaks at a few threads, then declines as loopback
traffic drains PCIe bandwidth. ALock (no loopback) keeps scaling.

One Experiment covers every (tpn, alg, seed) point; each tpn is its own
shape bucket (T changes), compiled once. Rows report mean±ci95 across
seeds.
"""
from benchmarks.common import emit, experiment, mops, us_per_op, wl
from repro.experiments import ExecOptions

TPNS = (1, 2, 4, 8, 12, 16)


def main(n_seeds: int = 1, options: ExecOptions | None = None) -> None:
    exp = experiment("fig1", n_seeds=n_seeds, options=options)
    for t in TPNS:
        for alg in ("spinlock", "alock"):
            exp.add(wl(alg, 1, t, 1000, 1.0), label=f"{alg}.t{t}")
    res = exp.run()
    peak = 0.0
    last = None
    for tpn in TPNS:
        r = res[f"spinlock.t{tpn}"]
        emit(f"fig1.spinlock.1node.t{tpn}", us_per_op(r), mops(r))
        peak = max(peak, r.mean_mops)
        last = r.mean_mops
        a = res[f"alock.t{tpn}"]
        emit(f"fig1.alock.1node.t{tpn}", us_per_op(a), mops(a))
    emit("fig1.spinlock.collapse_ratio", 0.0,
         f"{peak / max(last, 1e-9):.2f}x_peak_over_t16")


if __name__ == "__main__":
    main()

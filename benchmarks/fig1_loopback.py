"""Fig. 1: RDMA spinlock with 1k locks on 1 node — loopback saturation.

Paper claim: throughput peaks at a few threads, then declines as loopback
traffic drains PCIe bandwidth. ALock (no loopback) keeps scaling.

One ``sweep`` call covers every (tpn, alg, seed) point; each tpn is its own
shape bucket (T changes), compiled once. Rows report mean±ci95 across seeds.
"""
from benchmarks.common import cfg, emit, mops, sweep_all, us_per_op

TPNS = (1, 2, 4, 8, 12, 16)


def main(n_seeds: int = 1) -> None:
    cfgs = [cfg(alg, 1, t, 1000, 1.0) for t in TPNS
            for alg in ("spinlock", "alock")]
    res = sweep_all(cfgs, n_seeds=n_seeds)
    peak = 0.0
    last = None
    for tpn in TPNS:
        r = res[cfg("spinlock", 1, tpn, 1000, 1.0)]
        emit(f"fig1.spinlock.1node.t{tpn}", us_per_op(r), mops(r))
        peak = max(peak, r.mean_mops)
        last = r.mean_mops
        a = res[cfg("alock", 1, tpn, 1000, 1.0)]
        emit(f"fig1.alock.1node.t{tpn}", us_per_op(a), mops(a))
    emit("fig1.spinlock.collapse_ratio", 0.0,
         f"{peak / max(last, 1e-9):.2f}x_peak_over_t16")


if __name__ == "__main__":
    main()

"""Fig. 6: latency CDF percentiles (p50/p90/p99) per algorithm/workload.

Latency samples measure acquire->release only (think_ns excluded), matching
the paper's Fig. 6. One Experiment batches the whole grid; percentile
rows report mean±ci95 of the per-seed percentile across seeds.
"""
from benchmarks.common import emit, experiment, wl
from repro.experiments import ExecOptions

NODES, TPN = 10, 8
ALGS = ("alock", "spinlock", "mcs")


def _pct(br, q):
    m, ci = br.lat_pct(q)
    return f"{m/1e3:.2f}±{ci/1e3:.2f}us"


def main(n_seeds: int = 1, options: ExecOptions | None = None) -> None:
    grid = [(k, l) for k in (20, 100, 1000) for l in (0.85, 0.95, 1.0)]
    exp = experiment("fig6", n_seeds=n_seeds, options=options)
    for (k, l) in grid:
        for alg in ALGS:
            exp.add(wl(alg, NODES, TPN, k, l),
                    label=f"{alg}.k{k}.loc{int(l * 100)}")
    res = exp.run()
    for k, l in grid:
        rows = {}
        for alg in ALGS:
            br = res[f"{alg}.k{k}.loc{int(l * 100)}"]
            p50, _ = br.lat_pct(50)
            if not (p50 == p50):  # no completed ops at all
                continue
            rows[alg] = p50
            emit(f"fig6.{alg}.k{k}.loc{int(l*100)}", p50 / 1e3,
                 f"p50={_pct(br, 50)},p90={_pct(br, 90)},"
                 f"p99={_pct(br, 99)}")
        if "alock" in rows and "mcs" in rows:
            emit(f"fig6.p50gap.k{k}.loc{int(l*100)}", 0.0,
                 f"mcs_over_alock={rows['mcs']/max(rows['alock'],1e-9):.2f}x")


if __name__ == "__main__":
    main()

"""Fig. 6: latency CDF percentiles (p50/p90/p99) per algorithm/workload."""
import numpy as np

from benchmarks.common import emit, run

NODES, TPN = 10, 8


def main() -> None:
    for locks in (20, 100, 1000):
        for loc in (0.85, 0.95, 1.0):
            rows = {}
            for alg in ("alock", "spinlock", "mcs"):
                r = run(alg, NODES, TPN, locks, loc)
                lat = np.asarray(r.lat_ns)
                lat = lat[lat >= 0]
                if len(lat) == 0:
                    continue
                p50, p90, p99 = np.percentile(lat, [50, 90, 99])
                rows[alg] = p50
                emit(f"fig6.{alg}.k{locks}.loc{int(loc*100)}",
                     float(p50) / 1e3,
                     f"p50={p50/1e3:.2f}us,p90={p90/1e3:.2f}us,"
                     f"p99={p99/1e3:.2f}us")
            if "alock" in rows and "mcs" in rows:
                emit(f"fig6.p50gap.k{locks}.loc{int(loc*100)}", 0.0,
                     f"mcs_over_alock={rows['mcs']/max(rows['alock'],1e-9):.2f}x")


if __name__ == "__main__":
    main()

"""Fig. 4: budget study — speedup over (remote=5, local=5) baseline.

Paper: averaged over 95/90/85% locality on 20 nodes with 100 locks, raising
the remote budget to 20 while keeping the local budget at 5 improves
throughput by up to ~23%.
"""
import numpy as np

from benchmarks.common import emit, run, us_per_op

NODES, TPN, LOCKS = 20, 12, 100
LOCALITIES = (0.95, 0.90, 0.85)


def main() -> None:
    base = {}
    for loc in LOCALITIES:
        r = run("alock", NODES, TPN, LOCKS, loc, b=(5, 5))
        base[loc] = r.throughput_mops
    for rb in (5, 10, 20):
        sps = []
        for loc in LOCALITIES:
            r = run("alock", NODES, TPN, LOCKS, loc, b=(5, rb))
            sp = r.throughput_mops / max(base[loc], 1e-9)
            sps.append(sp)
            emit(f"fig4.alock.rb{rb}.loc{int(loc*100)}", us_per_op(r),
                 f"speedup={sp:.3f},reacq={r.reacquires},passes={r.passes}")
        emit(f"fig4.alock.rb{rb}.mean", 0.0,
             f"mean_speedup={np.mean(sps):.3f}")
    # budget-space sensitivity: tight budgets force frequent (expensive)
    # reacquires — the mechanism behind the paper's asymmetric choice
    for b in ((1, 1), (2, 2), (2, 8), (2, 20), (20, 5)):
        r = run("alock", NODES, TPN, LOCKS, 0.90, b=b)
        emit(f"fig4.alock.b{b[0]}_{b[1]}.loc90", us_per_op(r),
             f"{r.throughput_mops:.3f}Mops,reacq={r.reacquires}")


if __name__ == "__main__":
    main()

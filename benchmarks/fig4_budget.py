"""Fig. 4: budget study — speedup over (remote=5, local=5) baseline.

Paper: averaged over 95/90/85% locality on 20 nodes with 100 locks, raising
the remote budget to 20 while keeping the local budget at 5 improves
throughput by up to ~23%.

Every workload here shares one shape key (alock, T=240, N=20, K=100), so
the entire figure — baselines, budget grid, sensitivity strip, all seeds —
is a single compile + a single vmapped dispatch. Rows report mean±ci95.
"""
import numpy as np

from benchmarks.common import emit, experiment, mops, us_per_op, wl
from repro.experiments import ExecOptions

NODES, TPN, LOCKS = 20, 12, 100
LOCALITIES = (0.95, 0.90, 0.85)
B_SENS = ((1, 1), (2, 2), (2, 8), (2, 20), (20, 5))


def main(n_seeds: int = 1, options: ExecOptions | None = None) -> None:
    exp = experiment("fig4", n_seeds=n_seeds, options=options)
    for loc in LOCALITIES:
        exp.add(wl("alock", NODES, TPN, LOCKS, loc, b=(5, 5)),
                label=f"base.loc{int(loc * 100)}")
        for rb in (10, 20):
            exp.add(wl("alock", NODES, TPN, LOCKS, loc, b=(5, rb)),
                    label=f"rb{rb}.loc{int(loc * 100)}")
    for b in B_SENS:
        exp.add(wl("alock", NODES, TPN, LOCKS, 0.90, b=b),
                label=f"b{b[0]}_{b[1]}")
    res = exp.run()

    base = {loc: res[f"base.loc{int(loc * 100)}"].mean_mops
            for loc in LOCALITIES}
    for rb in (5, 10, 20):
        sps = []
        for loc in LOCALITIES:
            br = res[f"base.loc{int(loc * 100)}" if rb == 5
                     else f"rb{rb}.loc{int(loc * 100)}"]
            sp = br.mean_mops / max(base[loc], 1e-9)
            sps.append(sp)
            emit(f"fig4.alock.rb{rb}.loc{int(loc*100)}", us_per_op(br),
                 f"speedup={sp:.3f},reacq={br.reacquires.mean():.0f},"
                 f"passes={br.passes.mean():.0f}")
        emit(f"fig4.alock.rb{rb}.mean", 0.0,
             f"mean_speedup={np.mean(sps):.3f}")
    # budget-space sensitivity: tight budgets force frequent (expensive)
    # reacquires — the mechanism behind the paper's asymmetric choice
    for b in B_SENS:
        br = res[f"b{b[0]}_{b[1]}"]
        emit(f"fig4.alock.b{b[0]}_{b[1]}.loc90", us_per_op(br),
             f"{mops(br)},reacq={br.reacquires.mean():.0f}")


if __name__ == "__main__":
    main()

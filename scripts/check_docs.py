#!/usr/bin/env python
"""Docs-as-tests: doctest the public-API examples + check doc references.

Two gates, both wired into the CI ``docs`` leg:

  1. **Doctests** — every ``>>>`` example in the public-API module/function
     docstrings runs for real, ``python -m doctest`` style. Modules are
     **auto-discovered**: any ``src/repro/**/*.py`` whose source contains
     a ``>>>`` example is collected — there is no list to forget to
     update. A discovered module that fails to import, or whose examples
     doctest collects zero of (``>>>`` outside a docstring — written but
     silently never run), fails the build.
  2. **Reference check** — every markdown link target and every
     backtick-quoted file path in ``docs/*.md`` and ``README.md`` must
     exist in the tree, and dotted ``repro.*`` / ``benchmarks.*`` module
     references must resolve to source files. Renaming a module without
     updating the docs fails the build.

Usage: PYTHONPATH=src python scripts/check_docs.py [--skip-doctests]
Exit code: 0 clean, 1 on any failure (failures are listed).
"""
from __future__ import annotations

import argparse
import doctest
import importlib
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

def discover_doctest_modules() -> list[str]:
    """Every module under ``src/repro`` whose source contains a ``>>>``
    example, as dotted names (``__init__.py`` maps to its package).
    Discovery is textual so a module whose examples doctest cannot
    collect (e.g. ``>>>`` in a plain string) is still discovered — and
    then *fails* below, instead of silently never running."""
    src = REPO / "src"
    out = []
    for path in sorted((src / "repro").rglob("*.py")):
        if ">>>" not in path.read_text(encoding="utf-8"):
            continue
        rel = path.relative_to(src).with_suffix("")
        parts = rel.parts
        if parts[-1] == "__init__":
            parts = parts[:-1]
        out.append(".".join(parts))
    return out

# docs sources scanned by the reference checker
DOC_FILES = ["README.md", *sorted(
    str(p.relative_to(REPO)) for p in (REPO / "docs").glob("*.md"))]

_MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_CODE_REF = re.compile(r"`([^`\n]+)`")
_PATHY = re.compile(r"^[\w./-]+\.(py|md|yml|yaml|json|txt|toml|cfg)$")
_DOTTED = re.compile(r"^(repro|benchmarks)(\.[A-Za-z_]\w*)+$")


def run_doctests(names: list[str]) -> list[str]:
    failures = []
    for name in names:
        try:
            mod = importlib.import_module(name)
        except Exception as e:       # an unimportable public module IS stale
            failures.append(f"doctest {name}: import failed: {e!r}")
            continue
        res = doctest.testmod(mod, verbose=False,
                              optionflags=doctest.ELLIPSIS)
        print(f"doctest {name}: {res.attempted} example(s), "
              f"{res.failed} failed", flush=True)
        if res.failed:
            failures.append(f"doctest {name}: {res.failed} of "
                            f"{res.attempted} example(s) failed")
        elif res.attempted == 0:
            failures.append(
                f"doctest {name}: source contains >>> examples but "
                f"doctest collected none — examples outside a docstring "
                f"are written-but-never-run documentation")
    return failures


def _module_resolves(dotted: str) -> bool:
    """``repro.workloads.lower`` and ``repro.workloads.Workload`` both
    count: trailing segments may be attributes, so any prefix of at least
    two segments that maps to a source file under src/ (or benchmarks/)
    passes; ``repro.nonexistent`` does not."""
    parts = dotted.split(".")
    roots = {"repro": REPO / "src" / "repro",
             "benchmarks": REPO / "benchmarks"}
    base = roots[parts[0]]
    for depth in range(len(parts), 1, -1):
        sub = base.joinpath(*parts[1:depth])
        # a bare directory counts: repro.coord is a namespace package
        if sub.with_suffix(".py").exists() or sub.is_dir():
            return True
    return False


def check_doc_references(doc_files: list[str]) -> list[str]:
    failures = []
    for rel in doc_files:
        path = REPO / rel
        if not path.exists():
            failures.append(f"{rel}: listed doc file does not exist")
            continue
        text = path.read_text()
        refs: list[tuple[str, str]] = []
        for m in _MD_LINK.finditer(text):
            target = m.group(1).split("#")[0]
            if not target or target.startswith(("http://", "https://",
                                                "mailto:")):
                continue
            refs.append(("link", target))
        for m in _CODE_REF.finditer(text):
            tok = m.group(1).strip().split("#")[0].strip()
            tok = tok.split(":")[0]          # `src/x.py:123` line anchors
            if _PATHY.match(tok) and ("/" in tok or tok.endswith(".md")):
                refs.append(("path", tok))
            elif _DOTTED.match(tok):
                if not _module_resolves(tok):
                    failures.append(f"{rel}: stale module reference "
                                    f"`{tok}`")
        n_checked = 0
        for kind, target in refs:
            cand = (path.parent / target, REPO / target)
            if not any(c.exists() for c in cand):
                failures.append(f"{rel}: {kind} target {target!r} not "
                                f"found (checked relative to the doc and "
                                f"the repo root)")
            n_checked += 1
        print(f"refcheck {rel}: {n_checked} file ref(s) checked", flush=True)
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--skip-doctests", action="store_true",
                    help="only run the markdown reference checker")
    args = ap.parse_args()

    failures = check_doc_references(DOC_FILES)
    if not args.skip_doctests:
        failures += run_doctests(discover_doctest_modules())

    if failures:
        print("\nDOCS CHECK FAILED:", flush=True)
        for f in failures:
            print(f"  - {f}", flush=True)
        return 1
    print("\ndocs check: all clean", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())

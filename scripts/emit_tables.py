"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from artifacts."""
import glob
import json


def rows(pattern="artifacts/dryrun/*.json"):
    out = []
    for f in sorted(glob.glob(pattern)):
        if "__opt" in f:
            continue
        out.append(json.load(open(f)))
    return out


def fmt(x, n=2):
    return f"{x:.{n}f}" if isinstance(x, (int, float)) else str(x)


def main():
    rs = rows()
    print("| arch | shape | mesh | status | compile_s | flops/chip | "
          "compute_s | memory_s | coll_s (prompt) | coll_link_s | dominant |"
          " useful | temp GB/chip |")
    print("|---|---|---|---|---|---|---|---|---|---|---|---|---|")
    for r in rs:
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"{r['status']}: {r.get('reason', r.get('error',''))[:40]}"
                  " |  |  |  |  |  |  |  |  |  |")
            continue
        t = r["roofline"]
        mem = (r.get("memory") or {}).get("temp_size_in_bytes", 0) / 1e9
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
              f"{fmt(r['compile_s'],1)} | {r['flops_per_chip']:.2e} | "
              f"{t['compute_s']:.2e} | {t['memory_s']:.2e} | "
              f"{t['collective_s']:.2e} | {t['collective_link_s']:.2e} | "
              f"{t['dominant'].replace('_s','')} | "
              f"{fmt(r['useful_flops_ratio'],3)} | {fmt(mem,1)} |")


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512" + (" --xla_dump_to=" + os.environ["XDUMP"] if os.environ.get("XDUMP") else "")
import sys
import dataclasses
import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.launch import mesh as mesh_lib
from repro.models import model as M
from repro.models.params import tree_structs
from repro.parallel import sharding as sh
from repro.launch.dryrun import input_specs

arch = sys.argv[1] if len(sys.argv) > 1 else "gemma3-1b"
variant = sys.argv[2] if len(sys.argv) > 2 else "grad"

cfg = get_config(arch)
if variant.endswith("-naive"):
    cfg = dataclasses.replace(cfg, attn_impl="naive")
if variant.endswith("-noremat"):
    cfg = dataclasses.replace(cfg, remat="none")
shape = SHAPES["train_4k"]
mesh = mesh_lib.make_production_mesh(multi_pod=False)
rules = sh.rules_for_shape("train", kv_divisible=False)

pspecs = M.model_specs(cfg)
p_structs = tree_structs(pspecs)
p_shard = sh.tree_shardings(pspecs, rules, mesh)
ins = input_specs(arch, "train_4k")
b_structs = {k: v[0] for k, v in ins["batch"].items()}
b_shard = {k: sh.named_sharding(v[0].shape, v[1], rules, mesh)
           for k, v in ins["batch"].items()}

if variant.startswith("fwd"):
    def fn(params, batch):
        x, aux, _ = M.forward_hidden(cfg, params, batch)
        return x.sum()
elif variant.startswith("loss"):
    def fn(params, batch):
        return M.loss_fn(cfg, params, batch)[0]
elif variant.startswith("gradtrunk"):
    def fn(params, batch):
        def f(p):
            x, aux, _ = M.forward_hidden(cfg, p, batch)
            return x.astype(jnp.float32).sum()
        return jax.grad(f)(params)
else:  # grad
    def fn(params, batch):
        return jax.grad(lambda p: M.loss_fn(cfg, p, batch)[0])(params)

with mesh, sh.sharding_ctx(mesh, rules):
    c = jax.jit(fn, in_shardings=(p_shard, b_shard)).lower(
        p_structs, b_structs).compile()
m = c.memory_analysis()
print(variant, arch, "temp GB:", m.temp_size_in_bytes / 1e9,
      "args GB:", m.argument_size_in_bytes / 1e9)

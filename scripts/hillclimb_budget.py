"""Hillclimb cell 3 (paper-representative): budgeted cross-pod gradient
sync for qwen2-72b x train_4k on the 2x16x16 multi-pod mesh.

Baseline: the synchronous train_step (artifacts/dryrun/
qwen2-72b__train_4k__multi.json) — every step pays the cross-pod reduction.
Optimized: the cohort pair (local_accum_step / sync_step). We lower both,
split collective traffic by replica-group span (intra-pod vs cross-pod),
and report the amortized per-microbatch cost for remote budgets k.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.launch import mesh as mesh_lib
from repro.launch.hlo_analysis import parse_collectives
from repro.models import model as M
from repro.models.params import is_spec, tree_structs
from repro.parallel import sharding as sh
from repro.parallel.collectives import make_budgeted_steps
from repro.train.optimizer import OptConfig, opt_state_specs

ARCH, SEQ, GB, NPOD = "qwen2-72b", 4096, 256, 2
SDS = jax.ShapeDtypeStruct


def main():
    cfg = get_config(ARCH)
    mesh = mesh_lib.make_production_mesh(multi_pod=True)
    rules = sh.rules_for_shape("train", kv_divisible=False)
    pspecs = M.model_specs(cfg)
    p_structs = tree_structs(pspecs)
    p_shard = sh.tree_shardings(pspecs, rules, mesh)
    p_pspecs = sh.tree_pspecs(pspecs, rules, mesh)

    def acc_shard(p):
        return NamedSharding(mesh, P(*(("pod",) + tuple(p))))

    acc_structs = jax.tree_util.tree_map(
        lambda s: SDS((NPOD,) + s.shape, jnp.float32), pspecs,
        is_leaf=is_spec)
    acc_sh = jax.tree_util.tree_map(acc_shard, p_pspecs,
                                    is_leaf=lambda x: isinstance(x, P))
    o_specs = opt_state_specs(pspecs)
    o_structs = tree_structs(o_specs)
    o_shard = sh.tree_shardings(o_specs, rules, mesh)

    batch_structs = {
        "tokens": SDS((NPOD, GB // NPOD, SEQ), jnp.int32),
        "labels": SDS((NPOD, GB // NPOD, SEQ), jnp.int32)}
    batch_sh = {k: NamedSharding(mesh, P("pod", "data", None))
                for k in batch_structs}

    init_acc, local_step, sync_step = make_budgeted_steps(
        cfg, OptConfig(), mesh, NPOD)

    out = {"arch": ARCH, "mesh": "multi(2x16x16)"}
    with mesh, sh.sharding_ctx(mesh, rules):
        cl = jax.jit(local_step,
                     in_shardings=(p_shard, acc_sh, batch_sh)).lower(
            p_structs, acc_structs, batch_structs).compile()
        cs = jax.jit(sync_step,
                     in_shardings=(p_shard, o_shard, acc_sh, None, None)
                     ).lower(p_structs, o_structs, acc_structs,
                             SDS((), jnp.int32),
                             SDS((), jnp.int32)).compile()
    for name, comp in (("local", cl), ("sync", cs)):
        st = parse_collectives(comp.as_text(), 512)
        # split by replica-group span: cross-pod collectives have groups
        # whose size is a multiple of the pod axis span (2) combined with
        # others; identify by group size > 256 (crossing pod boundary)
        cross = sum(o["link_bytes"] for o in st.ops if o["group"] > 256
                    or o["group"] == 2)
        intra = st.link_bytes - cross
        out[name] = {"link_bytes": st.link_bytes, "cross_pod": cross,
                     "intra_pod": intra, "by_kind": st.by_kind()}
        mem = comp.memory_analysis()
        out[name]["temp_gb"] = mem.temp_size_in_bytes / 1e9
    for k in (1, 2, 4, 8):
        amort = out["local"]["link_bytes"] + out["sync"]["link_bytes"] / k
        amort_cross = (out["local"]["cross_pod"] +
                       out["sync"]["cross_pod"] / k)
        out[f"budget_{k}"] = {
            "amortized_link_bytes_per_microbatch": amort,
            "amortized_cross_pod_bytes": amort_cross,
            "collective_link_s": amort / (2 * mesh_lib.ICI_BW)}
    os.makedirs("artifacts/hillclimb", exist_ok=True)
    with open("artifacts/hillclimb/budget_qwen72.json", "w") as f:
        json.dump(out, f, indent=1, default=str)
    for k in (1, 2, 4, 8):
        d = out[f"budget_{k}"]
        print(f"budget={k}: amortized link bytes/microbatch="
              f"{d['amortized_link_bytes_per_microbatch']:.3e} "
              f"(cross-pod {d['amortized_cross_pod_bytes']:.3e}) "
              f"-> {d['collective_link_s']:.2f}s", flush=True)


if __name__ == "__main__":
    main()
